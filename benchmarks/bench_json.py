"""NaN-safe JSON emission for BENCH_*.json artifacts.

``json.dump`` happily serializes ``float("nan")`` as the bare token
``NaN`` — not valid JSON, so every downstream consumer (CI ``--check``
re-parsers, dashboards) chokes on the whole file because one warm-hit
record lacked a ``default_score``. ``sanitize`` replaces every non-finite
float with ``None`` and flags it (``<key>_missing: true``) so the absence
is explicit instead of corrupting; ``write_bench`` additionally passes
``allow_nan=False`` so any non-finite value that slips past sanitisation
is a loud error rather than an invalid artifact.
"""
from __future__ import annotations

import json
import math
import os
from typing import Any, Dict


def _bad(v: Any) -> bool:
    return isinstance(v, float) and not math.isfinite(v)


def sanitize(obj: Any) -> Any:
    """Deep-copy ``obj`` with non-finite floats replaced by ``None``. Dict
    entries additionally gain a ``<key>_missing: true`` sibling so report
    readers can tell "absent" from "never computed"."""
    if isinstance(obj, dict):
        out: Dict = {}
        for k, v in obj.items():
            if _bad(v):
                out[k] = None
                out.setdefault(f"{k}_missing", True)
            else:
                out[k] = sanitize(v)
        return out
    if isinstance(obj, (list, tuple)):
        return [None if _bad(v) else sanitize(v) for v in obj]
    return obj


def write_bench(obj: Any, path: str) -> Any:
    """Sanitize + write a benchmark result as strictly valid JSON; returns
    the sanitized object (what the file actually says)."""
    clean = sanitize(obj)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(clean, f, indent=2, sort_keys=True, default=float,
                  allow_nan=False)
        f.write("\n")
    return clean
