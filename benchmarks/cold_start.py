"""Serve cold-start: first-call latency with vs without an AOT kernel bundle.

The paper's Table II metric (``benchmarks/compile_time.py``) measures what
*tuning* costs; this benchmark measures what a serving process pays on its
first request — the Pallas trace + lower + compile of every kernel it is
about to run — and what remains of that cost when the kernels arrive as a
golden release's ahead-of-time compiled bundle (``python -m repro.tuna
golden --bundle``).

Method: tune the benchmark shapes into an in-memory store, promote them to
a golden release, build the bundle, then time two cold starts per
iteration, each from a cleared jax compilation cache and cold block-spec
memos:

* **unbundled** — warm *schedule* snapshot installed (block-spec picks are
  O(1) lookups in both runs, so the delta is compilation, not search),
  first ``ops.matmul`` + ``ops.attention`` call pays the full Pallas
  trace+compile;
* **bundled** — ``ops.use_kernel_bundle`` (bundle load + executable
  deserialization timed as part of the cold start, because it is), first
  calls dispatch to the deserialized executables.

Both runs use identical block configs, so outputs are comparable
bit-for-bit. ``--check`` exits 1 unless the bundled cold start is strictly
faster, performed **zero** Pallas traces (``kernels.ops
.pallas_trace_counts``), and matched the unbundled outputs. Emits
``BENCH_compile.json``, folding in ``compile_time_comparison`` so the
tune-time and serve-time halves of the story live in one artifact:

    PYTHONPATH=src python -m benchmarks.cold_start --check \
        --out BENCH_compile.json
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tuner
from repro.kernels import ops

PARITY_ATOL = 2e-4  # f32 + identical blocks: expected 0.0, tolerance for
#                     backend-revision drift in reduction order


def _sync(x) -> None:
    np.asarray(x)  # host transfer = execution barrier, interpret-safe


def _tune_records(M: int, S: int, D: int):
    """Tune the benchmark shapes into a fresh in-memory store and return
    its records — real tuner output, so the golden release the bundle is
    built from carries the exact configs the unbundled run would pick."""
    from repro.tuna.db import ScheduleDatabase

    db = ScheduleDatabase()
    tuner.set_default_db(db)
    try:
        tuner.tuned_matmul_blocks(M, M, M, 4)
        ops.tuned_flash_blocks(S, D, 4)
    finally:
        tuner.set_default_db(None)
    return db.records()


def _cold_state() -> None:
    """Per-measurement reset: compiled-computation cache, block-spec
    memos, and the Pallas trace counters all back to process-start."""
    jax.clear_caches()
    tuner._clear_memos()
    ops.reset_pallas_trace_counts()


def run_benchmark(M: int = 256, S: int = 128, D: int = 64,
                  iters: int = 3, seed: int = 0,
                  ct_configs: int = 8, ct_iters: int = 2,
                  workdir: str = None) -> Dict:
    from repro.tuna.cache import ScheduleCache
    from repro.tuna.golden import GoldenManager, build_kernel_bundle

    workdir = workdir or tempfile.mkdtemp(prefix="tuna_cold_start_")
    records = _tune_records(M, S, D)
    mgr = GoldenManager(workdir)
    info = mgr.promote(records, "tpu_v5e", source="benchmarks/cold_start")
    _, release = mgr.load_release(info.path)
    t0 = time.perf_counter()
    bundle_info = build_kernel_bundle(release, workdir, "tpu_v5e",
                                      golden_name=info.name)
    bundle_build_s = time.perf_counter() - t0

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((M, M)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((M, M)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((1, 1, S, D)), jnp.float32)

    snapshot = ScheduleCache(records, source="cold_start")

    unbundled = {"wall_s": [], "matmul_s": [], "flash_s": []}
    bundled = {"wall_s": [], "load_s": [], "matmul_s": [], "flash_s": []}
    out_u = out_b = att_u = att_b = None
    traces_u = traces_b = None

    for _ in range(iters):
        # -- unbundled cold start (warm snapshot, cold compiler) ----------
        ops.use_kernel_bundle(None)
        tuner.set_default_cache(snapshot)
        _cold_state()
        t0 = time.perf_counter()
        out_u = ops.matmul(x, y, force_pallas=True)
        _sync(out_u)
        t1 = time.perf_counter()
        att_u = ops.attention(q, q, q, force_pallas=True)
        _sync(att_u)
        t2 = time.perf_counter()
        traces_u = ops.pallas_trace_counts()
        unbundled["matmul_s"].append(t1 - t0)
        unbundled["flash_s"].append(t2 - t1)
        unbundled["wall_s"].append(t2 - t0)

        # -- bundled cold start (load timed in: it is the cold path) ------
        tuner.set_default_cache(None)
        ops.use_kernel_bundle(None)  # drop the deserialized-executable memo
        _cold_state()
        t0 = time.perf_counter()
        ops.use_kernel_bundle(bundle_info.path)
        t_load = time.perf_counter()
        out_b = ops.matmul(x, y, force_pallas=True)
        _sync(out_b)
        t1 = time.perf_counter()
        att_b = ops.attention(q, q, q, force_pallas=True)
        _sync(att_b)
        t2 = time.perf_counter()
        traces_b = ops.pallas_trace_counts()
        bundled["load_s"].append(t_load - t0)
        bundled["matmul_s"].append(t1 - t_load)
        bundled["flash_s"].append(t2 - t1)
        bundled["wall_s"].append(t2 - t0)
        ops.use_kernel_bundle(None)

    max_diff = float(max(
        np.abs(np.asarray(out_u) - np.asarray(out_b)).max(),
        np.abs(np.asarray(att_u) - np.asarray(att_b)).max()))
    best_u = min(unbundled["wall_s"])
    best_b = min(bundled["wall_s"])
    from benchmarks.compile_time import compile_time_comparison

    result = {
        "schema": "bench-compile-v1",
        "shapes": {"matmul": [M, M, M], "flash": [1, 1, S, D],
                   "dtype": "float32"},
        "iters": iters,
        "cold_start": {
            "unbundled": {
                "wall_s": best_u,
                "matmul_s": min(unbundled["matmul_s"]),
                "flash_s": min(unbundled["flash_s"]),
                "all_wall_s": unbundled["wall_s"],
                "pallas_traces": traces_u,
            },
            "bundled": {
                "wall_s": best_b,
                "bundle_load_s": min(bundled["load_s"]),
                "matmul_s": min(bundled["matmul_s"]),
                "flash_s": min(bundled["flash_s"]),
                "all_wall_s": bundled["wall_s"],
                "pallas_traces": traces_b,
            },
            "speedup": best_u / max(best_b, 1e-9),
            "parity": {"ok": max_diff <= PARITY_ATOL,
                       "max_abs_diff": max_diff},
        },
        "bundle": {
            "name": bundle_info.name,
            "entries": bundle_info.entries,
            "schedules": bundle_info.schedules,
            "build_s": bundle_build_s,
            "golden": info.name,
        },
        "compile_time_comparison": compile_time_comparison(
            n_configs=ct_configs, iters=ct_iters, seed=seed),
    }
    return result


def check(result: Dict) -> list:
    """Acceptance gates; returns the list of violated ones (empty = pass)."""
    cs = result["cold_start"]
    bad = []
    if not cs["parity"]["ok"]:
        bad.append(f"bundled outputs diverge from unbundled "
                   f"(max_abs_diff={cs['parity']['max_abs_diff']:.2e})")
    traces = cs["bundled"]["pallas_traces"]
    if any(traces.values()):
        bad.append(f"bundled cold start traced Pallas kernels: {traces} "
                   f"(must be zero — the bundle exists so it doesn't)")
    if sum(cs["unbundled"]["pallas_traces"].values()) < 2:
        bad.append(f"unbundled cold start did not trace both kernels "
                   f"({cs['unbundled']['pallas_traces']}) — the baseline "
                   f"is not measuring compilation")
    if cs["bundled"]["wall_s"] >= cs["unbundled"]["wall_s"]:
        bad.append(f"bundled cold start not strictly faster: "
                   f"{cs['bundled']['wall_s']:.4f}s vs "
                   f"{cs['unbundled']['wall_s']:.4f}s")
    return bad


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_compile.json")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless the bundled cold start is strictly "
                         "faster, traced zero Pallas kernels, and matched "
                         "the unbundled outputs")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--matmul", type=int, default=256, metavar="M",
                    help="square matmul dimension")
    ap.add_argument("--seq", type=int, default=128, help="flash seq length")
    ap.add_argument("--head", type=int, default=64, help="flash head dim")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ct-configs", type=int, default=8,
                    help="candidate count for the folded-in "
                         "compile_time_comparison")
    ap.add_argument("--workdir", default=None,
                    help="where the golden release + bundle land (default: "
                         "a temp dir)")
    args = ap.parse_args()

    result = run_benchmark(M=args.matmul, S=args.seq, D=args.head,
                           iters=args.iters, seed=args.seed,
                           ct_configs=args.ct_configs, workdir=args.workdir)
    cs = result["cold_start"]
    print(f"[bench_compile] unbundled cold start: "
          f"{cs['unbundled']['wall_s']*1e3:.1f}ms "
          f"(traces {cs['unbundled']['pallas_traces']})")
    print(f"[bench_compile] bundled cold start:   "
          f"{cs['bundled']['wall_s']*1e3:.1f}ms "
          f"(load {cs['bundled']['bundle_load_s']*1e3:.1f}ms, "
          f"traces {cs['bundled']['pallas_traces']})")
    print(f"[bench_compile] speedup {cs['speedup']:.2f}x, parity "
          f"max|diff|={cs['parity']['max_abs_diff']:.2e}, bundle "
          f"{result['bundle']['entries']} kernels "
          f"built in {result['bundle']['build_s']:.2f}s")
    ct = result["compile_time_comparison"]
    print(f"[bench_compile] tune-time (Table II, {ct['n_configs']} cfgs): "
          f"static {ct['static_s']:.3f}s vs dynamic {ct['dynamic_s']:.3f}s "
          f"({ct['speedup']:.0f}x)")
    from benchmarks.bench_json import write_bench

    write_bench(result, args.out)
    print(f"[bench_compile] wrote {args.out}")
    if args.check:
        bad = check(result)
        for msg in bad:
            print(f"[bench_compile] CHECK FAILED: {msg}", file=sys.stderr)
        return 1 if bad else 0
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
