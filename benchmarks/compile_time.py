"""Paper Table II/III: compilation time & cost, Tuna vs dynamic tuning.

For the same candidate set, compare:
  * Tuna: pure static analysis wall time (parallel, no device execution);
  * Dynamic (AutoTVM role): measured execution of every candidate
    (sequential — measurements can't share the device).

Cost ($) = wall hours × instance price (paper Table III constants:
C5.9xlarge $1.53/h for the measuring fleet; Tuna runs on the same host).
Also reports the paper's headline ratio extrapolated to the full space size.
"""
from __future__ import annotations

import time
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core import tuner
from repro.core.spaces import MatmulSpace
from repro.core.tuner import _score_config, tune
from repro.hw import get_target

from benchmarks.measure import measure_config
from benchmarks.topk_ratio import sample_space

PRICE_PER_HOUR = 1.53  # EC2 C5.9xlarge (paper Table III)


def compile_time_comparison(M=512, N=512, K=512, n_configs: int = 16,
                            iters: int = 3, seed: int = 0) -> Dict:
    target = get_target("cpu_avx2")
    space = MatmulSpace(M, N, K, 4, target_kind="cpu")
    cfgs = sample_space(space, n_configs, seed)

    # every timed section starts from cold block-spec memos: sample_space /
    # earlier benchmark phases in the same process may have warmed the lru
    # caches, which would flatter static_s against dynamic_s
    tuner._clear_memos()
    t0 = time.perf_counter()
    for cfg in cfgs:
        _score_config(space, target, cfg)
    static_s = time.perf_counter() - t0

    rng = np.random.default_rng(seed)
    a = jnp.array(rng.standard_normal((M, K)), jnp.float32)
    b = jnp.array(rng.standard_normal((K, N)), jnp.float32)
    tuner._clear_memos()
    t0 = time.perf_counter()
    for cfg in cfgs:
        measure_config(M, N, K, cfg, a, b, iters=iters)
    dynamic_s = time.perf_counter() - t0

    # ES-driven search budget (the deployed flow) for reference; db=False so
    # a warm default store can't short-circuit the search being timed
    tuner._clear_memos()
    t0 = time.perf_counter()
    tune(space, target, iterations=8, population=12, db=False)
    es_s = time.perf_counter() - t0

    full = space.size()
    return {
        "n_configs": len(cfgs),
        "static_s": static_s,
        "dynamic_s": dynamic_s,
        "es_search_s": es_s,
        "speedup": dynamic_s / max(static_s, 1e-9),
        "static_cost_usd_full_space": static_s / len(cfgs) * full / 3600
        * PRICE_PER_HOUR,
        "dynamic_cost_usd_full_space": dynamic_s / len(cfgs) * full / 3600
        * PRICE_PER_HOUR,
        "full_space": full,
    }
