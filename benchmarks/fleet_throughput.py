"""Fleet-throughput benchmark: the controller daemon vs the manual flow.

Runs the same (op × target) tuning matrix three ways and emits
``BENCH_fleet.json``:

* **manual** — the pre-controller operator loop, by hand: ``run_fleet``
  over every shard, then ``sync``, then ``SnapshotManager.ensure`` (jobs
  per second, wall time to a published snapshot);
* **controller** — one ``FleetController.run()`` on an in-process
  ``mem://`` transport doing dispatch + sync + snapshot autonomously
  (time-to-converged-snapshot, controller overhead vs manual);
* **controller_healed** — the same run with one worker crash injected on
  its first dispatch: heal latency (failure observed → shard healed →
  re-tuned store published) and the convergence cost of a crash.

A parity verdict confirms all three converge to the same best-record
set (bookkeeping meta — provenance, tuned_at — stripped). ``--check``
exits non-zero if parity fails, the healed run did not actually heal, or
either controller run failed to converge.

    PYTHONPATH=src python -m benchmarks.fleet_throughput --check
    PYTHONPATH=src python -m benchmarks.fleet_throughput \
        --ops dense_256,batch_matmul --shards 4 --limit 128

Everything here is numpy-backed (no jax): what is measured is the
orchestration overhead, not kernel time.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time
from typing import Dict

from repro.tuna import fleet, orchestrator
from repro.tuna.cache import SnapshotManager
from repro.tuna.controller import ControllerConfig, FleetController
from repro.tuna.db import ScheduleDatabase, strip_bookkeeping
from repro.tuna.transport import MemoryTransport


def _strip(db: ScheduleDatabase):
    return [
        (r.op, r.target, r.version,
         json.dumps(r.config, sort_keys=True), r.score, r.evaluations,
         strip_bookkeeping(r.meta))
        for r in db.records()
    ]


def run_manual(jobs, num_shards: int, workdir: str, workers: int) -> Dict:
    """The by-hand operator flow the controller replaces: tune every
    shard, sync, snapshot."""
    base = os.path.join(workdir, "manual", "fleet.jsonl")
    t0 = time.perf_counter()
    report = fleet.run_fleet(jobs, num_shards, base, workers=workers)
    tune_s = time.perf_counter() - t0
    assert report.ok
    t1 = time.perf_counter()
    rep = fleet.sync(base, num_shards)
    sync_s = time.perf_counter() - t1
    t2 = time.perf_counter()
    info = SnapshotManager(base, base + ".snapshots").ensure()
    snapshot_s = time.perf_counter() - t2
    total = time.perf_counter() - t0
    return {
        "db": base,
        "jobs": len(jobs),
        "records": rep.keys,
        "snapshot_sha1": info.sha1,
        "tune_s": round(tune_s, 4),
        "sync_s": round(sync_s, 4),
        "snapshot_s": round(snapshot_s, 4),
        "time_to_snapshot_s": round(total, 4),
        "jobs_per_s": round(len(jobs) / max(total, 1e-9), 2),
    }


def run_controller(jobs, num_shards: int, workdir: str, workers: int,
                   crash_shard=None, tag: str = "controller") -> Dict:
    t = MemoryTransport(f"bench-{tag}")
    MemoryTransport.wipe(t.bucket)
    cfg = ControllerConfig(
        db=os.path.join(workdir, tag, "fleet.jsonl"),
        ops=[], targets=[],  # jobs passed explicitly below
        num_shards=num_shards, transport=t, poll_s=0.01,
        worker_procs=workers, inject_crash_shard=crash_shard, quiet=True)
    ctl = FleetController(cfg, jobs=jobs)
    t0 = time.perf_counter()
    rc = ctl.run(exit_when_converged=True)
    total = time.perf_counter() - t0

    heal_latency_s = None
    if crash_shard is not None:
        # failure observed -> healed shard's store published, from the
        # controller's own event log
        failed = [e["t"] for e in ctl.events
                  if e["event"] == "failed" and e["shard"] == crash_shard]
        done = [e["t"] for e in ctl.events
                if e["event"] == "done" and e["shard"] == crash_shard]
        if failed and done:
            heal_latency_s = round(done[-1] - failed[0], 4)
    m = ctl.metrics
    return {
        "db": cfg.db,
        "jobs": len(jobs),
        "converged": ctl.converged,
        "rc": rc,
        "rounds": ctl.rounds,
        "records": int(m.get("store_records")),
        "snapshot_sha1": getattr(ctl._snapshot_info, "sha1", None),
        "jobs_done": int(m.get("jobs_done_total")),
        "jobs_healed": int(m.get("jobs_healed_total")),
        "shards_healed": int(m.get("shards_healed_total")),
        "time_to_converged_snapshot_s": round(total, 4),
        "jobs_per_s": round(len(jobs) / max(total, 1e-9), 2),
        "heal_latency_s": heal_latency_s,
    }


def run_benchmark(ops, targets, num_shards: int, limit: int,
                  workers: int, workdir: str) -> Dict:
    jobs = orchestrator.jobs_for(ops, targets, limit=limit)
    manual = run_manual(jobs, num_shards, workdir, workers)
    ctl = run_controller(jobs, num_shards, workdir, workers)
    healed = run_controller(jobs, num_shards, workdir, workers,
                            crash_shard=0, tag="controller-healed")

    stores = {name: _strip(ScheduleDatabase(r["db"]))
              for name, r in (("manual", manual), ("controller", ctl),
                              ("controller_healed", healed))}
    parity = {
        "controller_vs_manual": stores["controller"] == stores["manual"],
        "healed_vs_manual": stores["controller_healed"] == stores["manual"],
    }
    parity["ok"] = all(parity.values())
    for r in (manual, ctl, healed):
        del r["db"]
    return {
        "ops": list(ops), "targets": list(targets),
        "num_shards": num_shards, "limit": limit, "jobs": len(jobs),
        "manual": manual, "controller": ctl, "controller_healed": healed,
        "parity": parity,
        "overhead": {
            "controller_vs_manual_s": round(
                ctl["time_to_converged_snapshot_s"]
                - manual["time_to_snapshot_s"], 4),
            "crash_convergence_cost_s": round(
                healed["time_to_converged_snapshot_s"]
                - ctl["time_to_converged_snapshot_s"], 4),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ops", default="dense_256,batch_matmul")
    ap.add_argument("--targets", default="tpu_v5e")
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--limit", type=int, default=64)
    ap.add_argument("--workers", type=int, default=1,
                    help="orchestrator pool size inside each shard worker")
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless all three flows converge to the "
                         "same store and the crash run actually healed")
    args = ap.parse_args()

    workdir = tempfile.mkdtemp(prefix="bench_fleet_")
    try:
        res = run_benchmark(args.ops.split(","), args.targets.split(","),
                            args.shards, args.limit, args.workers, workdir)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)
    from benchmarks.bench_json import write_bench

    write_bench(res, args.out)

    man, ctl, healed = (res["manual"], res["controller"],
                        res["controller_healed"])
    print(f"[bench_fleet] manual            {man['jobs']} jobs, "
          f"{man['jobs_per_s']:.2f} jobs/s, "
          f"snapshot in {man['time_to_snapshot_s']:.2f}s")
    print(f"[bench_fleet] controller        {ctl['jobs_done']} jobs, "
          f"{ctl['jobs_per_s']:.2f} jobs/s, "
          f"converged in {ctl['time_to_converged_snapshot_s']:.2f}s "
          f"({ctl['rounds']} rounds)")
    print(f"[bench_fleet] controller+crash  {healed['jobs_done']} jobs, "
          f"{healed['shards_healed']} shard healed in "
          f"{healed['heal_latency_s']}s, converged in "
          f"{healed['time_to_converged_snapshot_s']:.2f}s")
    print(f"[bench_fleet] parity={res['parity']['ok']} "
          f"controller_overhead={res['overhead']['controller_vs_manual_s']}s "
          f"-> {args.out}")
    if args.check:
        ok = (res["parity"]["ok"]
              and ctl["converged"] and healed["converged"]
              and healed["shards_healed"] == 1
              and healed["heal_latency_s"] is not None)
        if not ok:
            print("[bench_fleet] CHECK FAILED", file=sys.stderr)
            sys.exit(1)
        print("[bench_fleet] CHECK OK")


if __name__ == "__main__":
    main()
