"""Real-hardware measurement harness (the AutoTVM role on the host CPU).

A schedule config (bm, bn, bk, order) is realised as an XLA program of
``fori_loop`` + ``dynamic_slice`` block dots — XLA:CPU does NOT re-fuse these
into one GEMM, so block sizes genuinely change measured cache behaviour.
This supplies the ground-truth latencies for the paper's top-k-performance-
ratio experiment (Fig. 3/4) and the "AutoTVM Full" role in the compile-time
tables: Tuna never *uses* these timings to rank — it ranks statically; the
measurements only evaluate how good the static ranking is.
"""
from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def blocked_matmul(M: int, N: int, K: int, bm: int, bn: int, bk: int,
                   order: str = "ikj"):
    """Returns a jit-able f(A, B) -> C computing C via block dots in the
    given loop order (ikj: k innermost reuses the C block across k? no —
    order names the (i, k, j) nesting of block loops, innermost last)."""
    gm, gn, gk = M // bm, N // bn, K // bk

    def f(a, b):
        c0 = jnp.zeros((M, N), a.dtype)

        def body(t, c):
            if order == "ikj":
                i = t // (gk * gn)
                k = (t // gn) % gk
                j = t % gn
            elif order == "kij":
                k = t // (gm * gn)
                i = (t // gn) % gm
                j = t % gn
            else:  # ijk
                i = t // (gn * gk)
                j = (t // gk) % gn
                k = t % gk
            ab = jax.lax.dynamic_slice(a, (i * bm, k * bk), (bm, bk))
            bb = jax.lax.dynamic_slice(b, (k * bk, j * bn), (bk, bn))
            cb = jax.lax.dynamic_slice(c, (i * bm, j * bn), (bm, bn))
            cb = cb + ab @ bb
            return jax.lax.dynamic_update_slice(c, cb, (i * bm, j * bn))

        return jax.lax.fori_loop(0, gm * gn * gk, body, c0)

    return f


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (jit-compiled, blocked until ready)."""
    jf = jax.jit(fn)
    out = jf(*args)
    jax.block_until_ready(out)
    for _ in range(warmup - 1):
        jax.block_until_ready(jf(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(jf(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def measure_config(M: int, N: int, K: int, cfg: Dict, a, b,
                   iters: int = 5) -> float:
    fn = blocked_matmul(M, N, K, cfg["bm"], cfg["bn"], cfg["bk"],
                        cfg.get("order", "ikj"))
    return time_fn(fn, a, b, iters=iters)
