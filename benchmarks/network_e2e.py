"""Paper Table I: entire-network latency under three compilation regimes.

The "network" is the matmul workload of one transformer block ×depth (the
ops Tuna schedules — qkv/out projections, attention score/value GEMMs, MLP),
at reduced dims so the dynamic oracle stays measurable on one core:

  * Framework  — direct jnp.dot (XLA:CPU native, the TF/PT row's analogue)
  * Tuna       — per-op schedule chosen by pure static analysis
  * Oracle     — per-op schedule chosen by measuring every candidate
                 ("AutoTVM Full"); "AutoTVM Partial" = best random candidate
                 within Tuna's compile-time budget.

Reported per-op latencies are measured; the table sums them ×depth.
"""
from __future__ import annotations

import random
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spaces import MatmulSpace
from repro.core.tuner import _score_config
from repro.hw import get_target

from benchmarks.measure import measure_config, time_fn
from benchmarks.topk_ratio import sample_space


def block_matmuls(d: int = 256, s: int = 128, ff_mult: int = 4) -> List[Tuple]:
    """(name, M, N, K) for one decoder block at training-ish shapes."""
    return [
        ("qkv_proj", s, 3 * d, d),
        ("attn_out", s, d, d),
        ("mlp_up", s, ff_mult * d, d),
        ("mlp_down", s, d, ff_mult * d),
    ]


def network_latency(d: int = 256, s: int = 128, depth: int = 4,
                    n_configs: int = 12, iters: int = 3, seed: int = 0) -> Dict:
    target = get_target("cpu_avx2")
    rng = np.random.default_rng(seed)
    rows: Dict[str, float] = {"framework": 0.0, "tuna": 0.0, "oracle": 0.0,
                              "partial": 0.0}
    static_budget_s = 0.0
    for name, M, N, K in block_matmuls(d, s):
        a = jnp.array(rng.standard_normal((M, K)), jnp.float32)
        b = jnp.array(rng.standard_normal((K, N)), jnp.float32)
        rows["framework"] += time_fn(lambda x, y: x @ y, a, b, iters=iters)

        space = MatmulSpace(M, N, K, 4, target_kind="cpu")
        cfgs = sample_space(space, n_configs, seed)

        t0 = time.perf_counter()
        scored = sorted(cfgs, key=lambda c: _score_config(space, target, c))
        op_static_s = time.perf_counter() - t0
        static_budget_s += op_static_s
        times = {tuple(sorted(c.items())): measure_config(M, N, K, c, a, b,
                                                          iters=iters)
                 for c in cfgs}
        rows["tuna"] += times[tuple(sorted(scored[0].items()))]
        rows["oracle"] += min(times.values())
        # partial: random candidates measured within THIS op's static budget
        rnd = random.Random(seed)
        budget_each = max(1, int(op_static_s / max(
            float(np.mean(list(times.values()))) * (iters + 2), 1e-9)))
        pick = rnd.sample(cfgs, min(budget_each, len(cfgs)))
        rows["partial"] += min(times[tuple(sorted(c.items()))] for c in pick)

    return {
        **{k: v * depth * 1e3 for k, v in rows.items()},  # ms for the stack
        "tuna_vs_oracle": rows["oracle"] / max(rows["tuna"], 1e-12),
        "tuna_vs_framework": rows["framework"] / max(rows["tuna"], 1e-12),
        "tuna_vs_partial": rows["partial"] / max(rows["tuna"], 1e-12),
        "static_budget_s": static_budget_s,
    }
