"""§Roofline: three-term analysis per (arch × shape) from the dry-run.

    compute term    = FLOPs / (chips × 197e12)
    memory term     = HBM bytes / (chips × 819e9)
    collective term = collective operand bytes / (chips × 50e9)

Sources — and the one deviation from a naive reading of ``cost_analysis()``:
XLA's cost analysis counts while-loop bodies ONCE, so for scanned layers /
grad-accum loops its flops/bytes under-report by the trip counts (verified:
yi-6b train flops drop 10× when the accum loop is introduced). Therefore:

  * collective bytes come from the compiled HLO text with **recovered trip
    counts** (core/hlo_features.loop_scaled_collectives; per-device already —
    the global numerator is ×chips, which cancels the denominator's chips);
  * FLOPs and HBM bytes come from a **structural model** stated below,
    whose per-term formulas are auditable against the config (raw
    cost_analysis numbers are kept in the dry-run JSONs as diagnostics).

Structural FLOPs (per step, global):
  train   : 6·N_act·T·r  + 4·F_attn      (r = 4/3 full-remat recompute)
  prefill : 2·N_act·T    + F_attn
  decode  : 2·N_act·B    + F_attn_dec
  F_attn      = 2·B·S²·H·dh·L_attn       (causal: ·S²/2·4)
  F_attn_dec  = 4·B·S_cache·H·dh·L_attn
Structural HBM bytes (per device):
  train   : accum·(W_tp + A_micro) + U_opt + G_f32
            W_tp = all weights read once per microbatch from the post-gather
                   TP shard (FSDP re-gather traffic itself is collective);
            A_micro = c_act·L·tok_micro_dev·D·2  (c_act≈12: fwd+bwd+remat
                   reads/writes of block activations)
  prefill : W_tp + A_fwd
  decode  : W_tp(active experts only for MoE: dense dispatch reads all) +
            2·cache_bytes/chips
  U_opt   = (2·P + 2·M + 2·V) bytes of the update's read+write
  MODEL_FLOPS = 6·N_act·T (train) / 2·N_act·T (inference); the ratio
  MODEL_FLOPS / structural FLOPs exposes remat/attention overhead.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List, Optional

from repro.configs.base import get_config
from repro.launch.specs import SHAPES, recommended_state_dtype

PEAK = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = 256


def _attn_layers(cfg) -> int:
    return sum(1 for i in range(cfg.n_layers) if cfg.mixer_kind(i) == "attention")


def _state_bytes(dtype: str) -> int:
    return {"float32": 4, "bfloat16": 2, "int8": 1}[dtype]


def structural_terms(arch: str, shape_name: str, record: Dict) -> Dict[str, Any]:
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    kind, seq, batch = spec["kind"], spec["seq"], spec["batch"]
    chips = record.get("n_devices", CHIPS)
    mesh = record.get("mesh", {"data": 16, "model": 16})
    tp = mesh.get("model", 16)
    dp = chips // tp

    n_act = cfg.active_param_count()
    n_tot = cfg.param_count()
    l_attn = _attn_layers(cfg)
    h, dh = cfg.n_heads, cfg.head_dim
    tokens = batch * seq

    if kind == "train":
        accum = record.get("accum_steps", 1)
        f_attn = 2.0 * batch * seq * seq * h * dh * l_attn
        flops = 6.0 * n_act * tokens * (4.0 / 3.0) + 4.0 * f_attn
        tok_micro_dev = tokens // accum // dp
        a_micro = 12.0 * cfg.n_layers * tok_micro_dev * cfg.d_model * 2
        w_tp = n_tot * 2.0 / tp
        sb = _state_bytes(record.get("opt_state_dtype", "float32"))
        u_opt = (2 * 2 + 4 * sb) * n_tot / chips + 3 * 4 * n_tot / chips
        hbm = accum * (w_tp + a_micro) + u_opt
        model_flops = 6.0 * n_act * tokens
    elif kind == "prefill":
        f_attn = 2.0 * batch * seq * seq * h * dh * l_attn
        flops = 2.0 * n_act * tokens + f_attn
        hbm = n_tot * 2.0 / tp + 6.0 * cfg.n_layers * tokens / dp * cfg.d_model * 2
        model_flops = 2.0 * n_act * tokens
    else:  # decode
        f_attn = 4.0 * batch * seq * h * dh * l_attn
        flops = 2.0 * n_act * batch + f_attn
        cache_bytes = (
            2 * l_attn * batch * cfg.n_kv_heads * seq * dh * 2
        )
        hbm = n_tot * 2.0 / tp + 2.0 * cache_bytes / chips
        model_flops = 2.0 * n_act * batch

    coll_dev = sum(record.get("collective_operand_bytes_scaled",
                              record.get("collective_operand_bytes", {})).values())
    t_compute = flops / (chips * PEAK)
    t_memory = hbm / HBM_BW  # hbm already per device
    t_coll = coll_dev / LINK_BW  # per-device bytes over one 50 GB/s link
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    total = max(terms.values())
    frac = {
        "compute_s": t_compute / total if total else 0.0,
    }
    advice = {
        "compute_s": "compute-bound: raise MXU utilisation (tile alignment, "
                     "fewer remat recomputes)",
        "memory_s": "memory-bound: cut per-micro weight re-reads (lower "
                    "accum / keep weights resident) or activation traffic",
        "collective_s": "collective-bound: compress gradients (int8), reduce "
                        "per-micro FSDP reduces, overlap with compute",
    }[bottleneck]
    return {
        "arch": arch,
        "shape": shape_name,
        "flops": flops,
        "hbm_bytes_dev": hbm,
        "collective_bytes_dev": coll_dev,
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / flops if flops else 0.0,
        "roofline_fraction": (
            min(t_compute / total, 1.0) if total > 0 else 0.0
        ),
        "advice": advice,
    }


def load_records(dryrun_dir: str = "experiments/dryrun",
                 multi_pod: bool = False) -> List[Dict]:
    suffix = "_multipod.json" if multi_pod else "_pod.json"
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*" + suffix))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def full_table(dryrun_dir: str = "experiments/dryrun") -> List[Dict]:
    rows = []
    for rec in load_records(dryrun_dir):
        if rec.get("status") != "ok":
            continue
        rows.append(structural_terms(rec["arch"], rec["shape"], rec))
    return rows


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | bound | "
           "MODEL/struct | roofline frac |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} |"
        )
    return "\n".join(lines)
