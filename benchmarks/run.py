"""Benchmark harness — one entry per paper table. Prints
``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # quick (CI) settings
    BENCH_FULL=1 PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import os
import sys


def main() -> None:
    quick = os.environ.get("BENCH_FULL", "0") != "1"
    rows = []

    # Fig. 3/4 — top-k performance ratio per operator
    from benchmarks.topk_ratio import operator_suite

    for name, res in operator_suite(quick=quick):
        rows.append((f"topk_ratio/{name}", res["best_static_ms"] * 1e3,
                     f"ratio@10={res.get('ratio@10', res.get('ratio@5')):.3f}"
                     f";top1={res['top1_ratio']:.3f}"))

    # Table II/III — compile time & cost
    from benchmarks.compile_time import compile_time_comparison

    ct = compile_time_comparison(n_configs=8 if quick else 24,
                                 iters=2 if quick else 5)
    rows.append(("compile_time/static", ct["static_s"] / ct["n_configs"] * 1e6,
                 f"speedup_vs_dynamic={ct['speedup']:.1f}x"))
    rows.append(("compile_time/dynamic", ct["dynamic_s"] / ct["n_configs"] * 1e6,
                 f"full_space_cost=${ct['dynamic_cost_usd_full_space']:.2f}"
                 f"_vs_${ct['static_cost_usd_full_space']:.2f}"))

    # Table I — entire-network latency
    from benchmarks.network_e2e import network_latency

    nl = network_latency(d=128 if quick else 256, s=64 if quick else 128,
                         n_configs=8 if quick else 16,
                         iters=2 if quick else 5)
    rows.append(("network_e2e/tuna", nl["tuna"] * 1e3,
                 f"vs_oracle={nl['tuna_vs_oracle']:.3f}"
                 f";vs_framework={nl['tuna_vs_framework']:.2f}x"))

    # §Roofline — from dry-run artifacts (skipped if sweep not present)
    from benchmarks import roofline

    try:
        rl = roofline.full_table()
    except Exception:  # noqa: BLE001
        rl = []
    for r in rl:
        worst = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append((f"roofline/{r['arch']}/{r['shape']}", worst * 1e6,
                     f"bound={r['bottleneck']};frac={r['roofline_fraction']:.2f}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
