"""Serving-latency benchmark: continuous batching vs the wave fallback.

Drives one mixed prompt-length / mixed ``max_new`` workload through both
schedulers of ``repro.launch.serve`` (same model, same params, same request
set) plus the one-request-at-a-time greedy oracle, then emits
``BENCH_serve.json``:

* per-request TTFT and end-to-end latency with p50/p95/p99 per scheduler;
* ``wasted_slot_steps`` — slot-steps burned on pad/finished slots, the
  quantity continuous batching exists to drive down;
* a greedy parity verdict (token-for-token across both schedulers and the
  sequential oracle) — ``--check`` exits non-zero if parity fails or the
  continuous engine does not strictly beat the wave engine on waste.

    PYTHONPATH=src python -m benchmarks.serving_latency --check
    PYTHONPATH=src python -m benchmarks.serving_latency --arch yi-6b \
        --requests 12 --slots 3 --out BENCH_serve.json
"""
from __future__ import annotations

import argparse
import copy
import sys
from typing import Dict, List

import jax
import numpy as np

from repro.configs.base import get_config
from repro.launch.engine import Request, greedy_decode_reference
from repro.launch.serve import serve
from repro.models.model import Model

# mixed workload shape: (prompt_len, max_new) cycled over request ids —
# short-prompt/short-output requests sit next to long ones, which is
# exactly the regime where lockstep waves park slots idle
MIX = ((4, 4), (8, 12), (8, 4), (12, 8), (4, 10), (12, 3))


def make_workload(vocab: int, n_requests: int, seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen, mnew = MIX[i % len(MIX)]
        reqs.append(Request(i, list(rng.integers(0, vocab, plen)), mnew))
    return reqs


def run_benchmark(arch: str = "yi_6b", reduced: bool = True,
                  n_requests: int = 12, slots: int = 3,
                  seed: int = 0) -> Dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    base = make_workload(cfg.vocab, n_requests, seed)
    cap = max(len(r.prompt) + r.max_new for r in base) + 2

    results: Dict[str, Dict] = {}
    outputs: Dict[str, Dict[int, List[int]]] = {}
    for scheduler in ("wave", "continuous"):
        reqs = copy.deepcopy(base)
        results[scheduler] = serve(model, params, reqs, slots=slots, cap=cap,
                                   scheduler=scheduler)
        outputs[scheduler] = {r.rid: list(r.out) for r in reqs}
    outputs["sequential"] = {
        r.rid: greedy_decode_reference(model, params, r.prompt, r.max_new, cap)
        for r in base
    }

    parity = {
        pair: outputs["continuous"] == outputs[pair]
        for pair in ("wave", "sequential")
    }
    wave, cont = results["wave"], results["continuous"]
    return {
        "arch": cfg.name, "requests": n_requests, "slots": slots,
        "cap": cap, "seed": seed,
        "workload": [{"rid": r.rid, "prompt_len": len(r.prompt),
                      "max_new": r.max_new} for r in base],
        "wave": wave, "continuous": cont,
        "parity": {"continuous_vs_wave": parity["wave"],
                   "continuous_vs_sequential": parity["sequential"],
                   "ok": all(parity.values())},
        "speedup": {
            "tok_per_s": cont["tok_per_s"] / max(wave["tok_per_s"], 1e-9),
            "wasted_slot_steps_saved":
                wave["wasted_slot_steps"] - cont["wasted_slot_steps"],
            "latency_p95_ratio":
                cont["latency_s"]["p95"] / max(wave["latency_s"]["p95"], 1e-9),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--full", action="store_true",
                    help="run the full-size config (default: reduced CPU demo)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless greedy parity holds and the "
                         "continuous scheduler wastes strictly fewer "
                         "slot-steps than the wave scheduler")
    args = ap.parse_args()

    res = run_benchmark(arch=args.arch, reduced=not args.full,
                        n_requests=args.requests, slots=args.slots,
                        seed=args.seed)
    from benchmarks.bench_json import write_bench

    write_bench(res, args.out)
    for s in ("wave", "continuous"):
        r = res[s]
        print(f"[bench_serve] {s:11s} {r['tokens']} tok, "
              f"{r['tok_per_s']:.1f} tok/s, wasted={r['wasted_slot_steps']}, "
              f"ttft p95={r['ttft_s']['p95'] * 1e3:.1f}ms, "
              f"latency p50/p95/p99="
              f"{r['latency_s']['p50'] * 1e3:.0f}/"
              f"{r['latency_s']['p95'] * 1e3:.0f}/"
              f"{r['latency_s']['p99'] * 1e3:.0f}ms")
    print(f"[bench_serve] parity={res['parity']['ok']} "
          f"speedup={res['speedup']['tok_per_s']:.2f}x "
          f"waste_saved={res['speedup']['wasted_slot_steps_saved']} "
          f"-> {args.out}")
    if args.check:
        ok = (res["parity"]["ok"]
              and res["continuous"]["wasted_slot_steps"]
              < res["wave"]["wasted_slot_steps"])
        if not ok:
            print("[bench_serve] CHECK FAILED", file=sys.stderr)
            sys.exit(1)
        print("[bench_serve] CHECK OK")


if __name__ == "__main__":
    main()
