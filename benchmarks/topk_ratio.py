"""Paper Fig. 3/4: top-k performance ratio, Tuna static ranking vs measured
ground truth, on the host CPU.

ratio_k = Σ latency(measured-oracle top-k) / Σ latency(Tuna top-k)

(paper definition with AutoTVM-full playing the oracle role; → 1.0 means the
static model picks schedules as good as full on-device tuning). Operators:
matmul, batch_matmul, conv2d (im2col-reduced — its GEMM schedule is what
Tuna ranks). The candidate set is a seeded random sample of the space.

``--learned <artifact>`` additionally scores the *hybrid* ranking (static
``cm1`` prunes, the ``repro.core.learned`` ranker re-orders the top
candidates — zero extra measurements, the same ``times`` table serves both
rankings) and reports ``hybrid_ratio@k`` next to ``ratio@k``; ``--check``
gates hybrid ≥ static on the mean across operators. ``--collect`` appends
every per-config measurement to the store as a ``cm1-meas``-lineage record
— the ground-truth training set ``python -m repro.tuna train`` fits from.

Run: ``python -m benchmarks.topk_ratio [--quick] [--db PATH] [--collect]
[--learned ARTIFACT] [--check] [--out BENCH.json]``
"""
from __future__ import annotations

import argparse
import random
import sys
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import op_registry
from repro.core.spaces import MatmulSpace
from repro.core.tuner import _score_config, record_version
from repro.hw import get_target

from benchmarks.measure import measure_config


def sample_space(space, n: int, seed: int = 0,
                 limit: Optional[int] = None) -> List[Dict]:
    """Seeded random sample of ``n`` configs. The candidate pool is the
    *whole* space by default; an explicit ``limit`` caps enumeration (and
    is reported loudly when it actually truncates — a silently-capped pool
    would make top-k coverage numbers look exhaustive when they aren't)."""
    size = space.size()
    cap = size if limit is None else min(limit, size)
    all_cfgs = list(space.enumerate(cap))
    if cap < size:
        print(f"[topk] {space.signature()}: enumeration truncated to "
              f"{cap} of {size} configs (limit={limit})", file=sys.stderr)
    rng = random.Random(seed)
    return all_cfgs if len(all_cfgs) <= n else rng.sample(all_cfgs, n)


def _tkey(cfg: Dict) -> Tuple:
    return tuple(sorted(cfg.items()))


def topk_ratio_matmul(
    M: int, N: int, K: int, n_configs: int = 24, ks=(10,), iters: int = 3,
    batch: int = 1, seed: int = 0, calibrated: bool = True,
    db=None, limit: Optional[int] = None,
    learned=None, rerank_top: int = 12, collect: bool = False,
    space=None,
) -> Dict:
    """Returns {'ratio@k':..., 'static_s':..., 'measure_s':...}. ``batch``
    reuses the same schedule space with a leading vmap (batch_matmul).
    With ``calibrated`` the linear coefficients come from the one-shot probe
    fit (core/calibrate.py, probe 256^3 with a disjoint seed) — search stays
    static; only the a_i change, exactly the paper's procedure.

    ``db`` (ScheduleDatabase or path) shares the repro.tuna store: the best
    static pick is written back (under a fingerprinted ``cm1-cal-<hash>``
    version when calibrated, since fitted coefficients are host-specific),
    and a pre-existing record is surfaced as ``warm_config`` in the
    result. ``collect`` additionally appends *every* measured (config,
    seconds) pair under the ``cm1-meas`` lineage — training data for the
    learned ranker, kept in the log even though the index only retains the
    per-key best.

    ``learned`` (a ``LearnedRanker`` or artifact path) reports the hybrid
    ranking side by side as ``hybrid_ratio@k``/``hybrid_top1_ratio``; the
    re-rank spends zero hardware measurements (the shared ``times`` table
    covers both rankings, so equal top-k sets give exactly equal ratios).

    ``space`` supplies an explicit registry-built schedule space whose
    GEMM core is (M, N, K) — e.g. the ``moe_dispatch`` op, whose cpu knobs
    are matmul's and whose grid factor rides in ``batch``. Records are
    written under *that* space's signature, so registry ops get measured
    ground truth end-to-end.
    """
    target = get_target("cpu_avx2")
    if db is not None:  # None stays off (unlike tune, no default-DB pull)
        from repro.core.tuner import resolve_db

        db = resolve_db(db)
    if learned is not None:
        from repro.core.tuner import resolve_learned

        learned = resolve_learned(learned)
    coeffs = None
    if calibrated:
        from repro.core.calibrate import cached_cpu_coeffs, coeffs_for_scoring

        fitted = cached_cpu_coeffs()
        if fitted:
            coeffs = coeffs_for_scoring(fitted)
    if space is None:
        space = MatmulSpace(M, N, K, 4, target_kind="cpu")
    cfgs = sample_space(space, n_configs, seed, limit=limit)

    t0 = time.perf_counter()
    scores = [(cfg, _score_config(space, target, cfg, coeffs))
              for cfg in cfgs]
    static_s = time.perf_counter() - t0

    rng = np.random.default_rng(seed)
    a = jnp.array(rng.standard_normal((M, K)), jnp.float32)
    b = jnp.array(rng.standard_normal((K, N)), jnp.float32)
    t0 = time.perf_counter()
    times = {}
    for cfg, _ in scores:
        times[_tkey(cfg)] = measure_config(M, N, K, cfg, a, b,
                                           iters=iters) * batch
    measure_s = time.perf_counter() - t0

    by_static = sorted(scores, key=lambda cs: cs[1])
    by_measured = sorted(scores, key=lambda cs: times[_tkey(cs[0])])

    out = {"static_s": static_s, "measure_s": measure_s,
           "n_configs": len(cfgs), "space_size": space.size(),
           "sample_truncated": (limit is not None and limit < space.size())}
    by_hybrid = None
    if learned is not None:
        t0 = time.perf_counter()
        by_hybrid = learned.rerank(space, target, by_static, top=rerank_top)
        out["hybrid_s"] = time.perf_counter() - t0
        out["learned_version"] = learned.version
    for k in ks:
        k = min(k, len(cfgs))
        t_static = sum(times[_tkey(c)] for c, _ in by_static[:k])
        t_oracle = sum(times[_tkey(c)] for c, _ in by_measured[:k])
        out[f"ratio@{k}"] = t_oracle / t_static
        if by_hybrid is not None:
            t_hybrid = sum(times[_tkey(c)] for c, _ in by_hybrid[:k])
            out[f"hybrid_ratio@{k}"] = t_oracle / t_hybrid
    # top-1 regret: chosen best vs true best
    best_static = times[_tkey(by_static[0][0])]
    best_oracle = times[_tkey(by_measured[0][0])]
    out["top1_ratio"] = best_oracle / best_static
    out["best_static_ms"] = best_static * 1e3
    out["best_oracle_ms"] = best_oracle * 1e3
    if by_hybrid is not None:
        out["hybrid_top1_ratio"] = best_oracle / times[_tkey(by_hybrid[0][0])]

    if db is not None:
        from repro.tuna.db import ScheduleRecord, stamp_tuned_at

        version = record_version(coeffs)
        if len(cfgs) < space.size():
            # best of a random sample, not the space optimum: must never be
            # warm-hit as if it were a search-grade record
            version += "-sample"
        warm = db.best(space.signature(), target.name, version=version)
        if warm is not None:
            out["warm_config"] = dict(warm.config)
        db.add(ScheduleRecord(
            op=space.signature(), target=target.name,
            config=dict(by_static[0][0]), score=by_static[0][1],
            evaluations=len(cfgs),
            meta={"strategy": "topk_static", "measured_ms": best_static * 1e3,
                  "oracle_ms": best_oracle * 1e3},
            version=version,
        ))
        if collect:
            from repro.core.learned import measured_version

            mv = measured_version()
            for cfg, _ in scores:
                # all samples share one key: the index keeps the fastest,
                # the append-only log keeps every (config, seconds) pair —
                # which is the part the trainer reads
                db.add(ScheduleRecord(
                    op=space.signature(), target=target.name,
                    config=dict(cfg), score=times[_tkey(cfg)],
                    evaluations=iters,
                    meta=stamp_tuned_at({"strategy": "measured_sample",
                                         "iters": iters, "batch": batch}),
                    version=mv,
                ))
            out["collected"] = len(scores)
    return out


# operator suite (paper: conv2d, conv2d_winograd, depthwise, batch_matmul)
def operator_suite(quick: bool = True, db=None, learned=None,
                   collect: bool = False, seed: int = 0,
                   ) -> List[Tuple[str, Dict]]:
    n = 16 if quick else 48
    it = 3 if quick else 7
    kw = dict(db=db, learned=learned, collect=collect, seed=seed)
    results = []
    results.append(
        ("matmul_256", topk_ratio_matmul(256, 256, 256, n, ks=(5, 10),
                                         iters=it, **kw))
    )
    results.append(
        ("matmul_512", topk_ratio_matmul(512, 512, 512, n, ks=(5, 10),
                                         iters=it, **kw))
    )
    # conv2d 14x14x256 -> 256, 3x3 via im2col: GEMM (H·W=196→pad 256, Cin·9, Cout)
    results.append(
        ("conv2d_im2col", topk_ratio_matmul(256, 256, 2304 // 3 * 3, n,
                                            ks=(5, 10), iters=it, **kw))
    )
    # batch_matmul: attention-shaped (S x dh x S), batch folded into timing
    results.append(
        ("batch_matmul", topk_ratio_matmul(128, 128, 64, n, ks=(5, 10),
                                           iters=it, batch=8, **kw))
    )
    # registry-defined model-zoo op: MoE token-dispatch GEMM. Its cpu knobs
    # are matmul's (bm/bn/bk/order/unroll_i) over the (C, F, D) core, and
    # the (B, E) dispatch grid rides in the timing batch factor.
    moe = op_registry.make_space(
        "moe_dispatch", {"B": 2, "E": 8, "C": 128, "D": 256, "F": 512},
        "cpu")
    results.append(
        ("moe_dispatch", topk_ratio_matmul(128, 512, 256, n, ks=(5, 10),
                                           iters=it, batch=16, space=moe,
                                           **kw))
    )
    return results


def _mean_ratios(results: List[Tuple[str, Dict]],
                 prefix: str) -> Optional[float]:
    vals = [v for _, res in results for key, v in res.items()
            if key.startswith(prefix + "@")]
    return sum(vals) / len(vals) if vals else None


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        description="top-k performance ratio: static cm1 vs measured "
                    "oracle, optionally vs the hybrid learned ranker")
    p.add_argument("--quick", action="store_true", default=True,
                   help="CI-sized candidate sets (default)")
    p.add_argument("--full", dest="quick", action="store_false",
                   help="paper-sized candidate sets")
    p.add_argument("--db", default=None,
                   help="schedule store to write winners (and --collect "
                        "samples) into")
    p.add_argument("--collect", action="store_true",
                   help="append every per-config measurement to --db under "
                        "the cm1-meas lineage (training data)")
    p.add_argument("--learned", default=None, metavar="ARTIFACT",
                   help="learned-ranker artifact (or latest pointer): "
                        "report the hybrid ranking side by side")
    p.add_argument("--check", action="store_true",
                   help="exit 1 unless mean hybrid ratio@k >= mean static "
                        "ratio@k (requires --learned)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default=None, help="write BENCH json here")
    args = p.parse_args(argv)
    if args.collect and not args.db:
        p.error("--collect requires --db")
    if args.check and not args.learned:
        p.error("--check requires --learned")

    learned = None
    if args.learned:
        from repro.core.tuner import resolve_learned

        learned = resolve_learned(args.learned)  # verified load, once
    results = operator_suite(quick=args.quick, db=args.db,
                             learned=learned, collect=args.collect,
                             seed=args.seed)
    static_mean = _mean_ratios(results, "ratio")
    hybrid_mean = _mean_ratios(results, "hybrid_ratio")
    for name, res in results:
        pairs = ", ".join(f"{k}={v:.4f}" for k, v in sorted(res.items())
                          if k.startswith(("ratio@", "hybrid_ratio@",
                                           "top1_ratio", "hybrid_top1")))
        print(f"{name:16s} {pairs}")
    summary = {"operators": dict(results),
               "static_mean_ratio": static_mean,
               "hybrid_mean_ratio": hybrid_mean,
               "seed": args.seed, "quick": args.quick}
    if hybrid_mean is not None:
        print(f"mean ratio@k     static={static_mean:.4f} "
              f"hybrid={hybrid_mean:.4f}")
    if args.check:
        # the shared times table makes equal top-k sets exactly equal, so
        # the >= gate is safe on ties; the epsilon only absorbs float
        # summation order
        ok = hybrid_mean is not None and hybrid_mean >= static_mean - 1e-9
        summary["check"] = {"ok": ok, "gate": "hybrid_mean >= static_mean"}
    if args.out:
        from benchmarks.bench_json import write_bench

        write_bench(summary, args.out)
        print(f"wrote {args.out}")
    if args.check:
        if not summary["check"]["ok"]:
            print(f"CHECK FAILED: hybrid mean ratio {hybrid_mean} < "
                  f"static mean ratio {static_mean}", file=sys.stderr)
            return 1
        print("CHECK OK: hybrid >= static")
    return 0


if __name__ == "__main__":
    sys.exit(main())
