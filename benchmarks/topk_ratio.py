"""Paper Fig. 3/4: top-k performance ratio, Tuna static ranking vs measured
ground truth, on the host CPU.

ratio_k = Σ latency(measured-oracle top-k) / Σ latency(Tuna-static top-k)

(paper definition with AutoTVM-full playing the oracle role; → 1.0 means the
static model picks schedules as good as full on-device tuning). Operators:
matmul, batch_matmul, conv2d (im2col-reduced — its GEMM schedule is what
Tuna ranks). The candidate set is a seeded random sample of the space.
"""
from __future__ import annotations

import random
import time
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.spaces import MatmulSpace
from repro.core.tuner import _score_config, record_version
from repro.hw import get_target

from benchmarks.measure import measure_config


def sample_space(space, n: int, seed: int = 0) -> List[Dict]:
    all_cfgs = list(space.enumerate(4096))
    rng = random.Random(seed)
    return all_cfgs if len(all_cfgs) <= n else rng.sample(all_cfgs, n)


def topk_ratio_matmul(
    M: int, N: int, K: int, n_configs: int = 24, ks=(10,), iters: int = 3,
    batch: int = 1, seed: int = 0, calibrated: bool = True,
    db=None,
) -> Dict:
    """Returns {'ratio@k':..., 'static_s':..., 'measure_s':...}. ``batch``
    reuses the same schedule space with a leading vmap (batch_matmul).
    With ``calibrated`` the linear coefficients come from the one-shot probe
    fit (core/calibrate.py, probe 256^3 with a disjoint seed) — search stays
    static; only the a_i change, exactly the paper's procedure.

    ``db`` (ScheduleDatabase or path) shares the repro.tuna store: the best
    static pick is written back (under a fingerprinted ``cm1-cal-<hash>``
    version when calibrated, since fitted coefficients are host-specific),
    and a pre-existing record is surfaced as ``warm_config`` in the
    result."""
    target = get_target("cpu_avx2")
    if db is not None:  # None stays off (unlike tune, no default-DB pull)
        from repro.core.tuner import resolve_db

        db = resolve_db(db)
    coeffs = None
    if calibrated:
        from repro.core.calibrate import cached_cpu_coeffs, coeffs_for_scoring

        fitted = cached_cpu_coeffs()
        if fitted:
            coeffs = coeffs_for_scoring(fitted)
    space = MatmulSpace(M, N, K, 4, target_kind="cpu")
    cfgs = sample_space(space, n_configs, seed)

    t0 = time.perf_counter()
    scores = [(cfg, _score_config(space, target, cfg, coeffs))
              for cfg in cfgs]
    static_s = time.perf_counter() - t0

    rng = np.random.default_rng(seed)
    a = jnp.array(rng.standard_normal((M, K)), jnp.float32)
    b = jnp.array(rng.standard_normal((K, N)), jnp.float32)
    t0 = time.perf_counter()
    times = {}
    for cfg, _ in scores:
        key = tuple(sorted(cfg.items()))
        times[key] = measure_config(M, N, K, cfg, a, b, iters=iters) * batch
    measure_s = time.perf_counter() - t0

    by_static = sorted(scores, key=lambda cs: cs[1])
    by_measured = sorted(scores, key=lambda cs: times[tuple(sorted(cs[0].items()))])

    out = {"static_s": static_s, "measure_s": measure_s,
           "n_configs": len(cfgs)}
    for k in ks:
        k = min(k, len(cfgs))
        t_static = sum(times[tuple(sorted(c.items()))] for c, _ in by_static[:k])
        t_oracle = sum(times[tuple(sorted(c.items()))] for c, _ in by_measured[:k])
        out[f"ratio@{k}"] = t_oracle / t_static
    # top-1 regret: chosen best vs true best
    best_static = times[tuple(sorted(by_static[0][0].items()))]
    best_oracle = times[tuple(sorted(by_measured[0][0].items()))]
    out["top1_ratio"] = best_oracle / best_static
    out["best_static_ms"] = best_static * 1e3
    out["best_oracle_ms"] = best_oracle * 1e3

    if db is not None:
        from repro.tuna.db import ScheduleRecord

        version = record_version(coeffs)
        if len(cfgs) < space.size():
            # best of a random sample, not the space optimum: must never be
            # warm-hit as if it were a search-grade record
            version += "-sample"
        warm = db.best(space.signature(), target.name, version=version)
        if warm is not None:
            out["warm_config"] = dict(warm.config)
        db.add(ScheduleRecord(
            op=space.signature(), target=target.name,
            config=dict(by_static[0][0]), score=by_static[0][1],
            evaluations=len(cfgs),
            meta={"strategy": "topk_static", "measured_ms": best_static * 1e3,
                  "oracle_ms": best_oracle * 1e3},
            version=version,
        ))
    return out


# operator suite (paper: conv2d, conv2d_winograd, depthwise, batch_matmul)
def operator_suite(quick: bool = True) -> List[Tuple[str, Dict]]:
    n = 16 if quick else 48
    it = 3 if quick else 7
    results = []
    results.append(
        ("matmul_256", topk_ratio_matmul(256, 256, 256, n, ks=(5, 10), iters=it))
    )
    results.append(
        ("matmul_512", topk_ratio_matmul(512, 512, 512, n, ks=(5, 10), iters=it))
    )
    # conv2d 14x14x256 -> 256, 3x3 via im2col: GEMM (H·W=196→pad 256, Cin·9, Cout)
    results.append(
        ("conv2d_im2col", topk_ratio_matmul(256, 256, 2304 // 3 * 3, n,
                                            ks=(5, 10), iters=it))
    )
    # batch_matmul: attention-shaped (S x dh x S), batch folded into timing
    results.append(
        ("batch_matmul", topk_ratio_matmul(128, 128, 64, n, ks=(5, 10),
                                           iters=it, batch=8))
    )
    return results
