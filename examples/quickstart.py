"""Quickstart: Tuna's static optimization loop in 60 seconds.

1. Define the operator + transformation space (Eq. 1's e and T_e).
2. Rank it with the hardware cost model — no TPU attached, no execution.
3. Materialise the winning schedule as a real Pallas kernel and validate it
   against the jnp oracle (interpret mode).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import MatmulSpace, tune, rank_space
from repro.hw import get_target
from repro.kernels import ref
from repro.kernels.matmul import matmul_pallas


def main() -> None:
    target = get_target("tpu_v5e")
    M = N = K = 2048
    space = MatmulSpace(M, N, K, dtype_bytes=2, target_kind="tpu")
    print(f"space: {space.size()} schedules for {M}x{N}x{K} bf16 matmul")

    # Evolution-strategies search with the static cost model as fitness
    res = tune(space, target, iterations=12, population=16, seed=0)
    dflt = ("unknown (warm hit without a stored default_score)"
            if res.default_score_missing else f"{res.default_score:.3e}")
    print(f"ES picked {res.config} score={res.score:.3e} "
          f"(default schedule: {dflt}; "
          f"{res.evaluations} static evals in {res.wall_seconds:.2f}s)")

    # exhaustive static ranking agrees?
    best, best_score = rank_space(space, target, limit=512)[0]
    print(f"exhaustive best {best} score={best_score:.3e}")

    # roofline context
    ideal = 2 * M * N * K / target.peak_flops_bf16
    print(f"predicted time vs bf16 compute roofline: "
          f"{res.score/ideal:.2f}x of ideal {ideal*1e6:.1f} us")

    # materialise + validate on a smaller instance (CPU interpret mode)
    m = n = k = 256
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    got = matmul_pallas(x, y, bm=min(res.config["bm"], m),
                        bn=min(res.config["bn"], n),
                        bk=min(res.config["bk"], k), interpret=True)
    err = float(jnp.abs(got - ref.matmul(x, y)).max())
    print(f"pallas kernel vs oracle max err: {err:.2e}")


if __name__ == "__main__":
    main()
