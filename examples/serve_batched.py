"""Batched serving example: prefill + lockstep decode over request waves.

    PYTHONPATH=src python examples/serve_batched.py --arch yi-6b --requests 6
"""
import argparse

import jax
import numpy as np

from repro.configs.base import get_config
from repro.launch.serve import Request, serve
from repro.models.model import Model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [Request(i, list(rng.integers(0, cfg.vocab, args.prompt_len)),
                    args.max_new) for i in range(args.requests)]
    stats = serve(model, params, reqs, slots=args.slots,
                  cap=args.prompt_len + args.max_new + 2)
    for r in reqs[:3]:
        print(f"req {r.rid}: prompt {r.prompt[:6]}... -> {r.out}")
    print(f"{stats['tokens']} tokens, {stats['tok_per_s']:.1f} tok/s, "
          f"{stats['engine_steps']} engine steps")


if __name__ == "__main__":
    main()
