"""End-to-end training driver example (deliverable b): train a reduced-family
model for a few hundred steps with the full production loop — deterministic
data, async checkpoints, failure recovery, straggler monitoring.

Any assigned arch works (--arch jamba-v0.1-52b trains a tiny hybrid
Mamba+MoE stack). Default runs ~200 steps of a yi-family model on learnable
periodic data so the loss visibly collapses.

    PYTHONPATH=src python examples/train_tiny.py --steps 200
    PYTHONPATH=src python examples/train_tiny.py --arch jamba-v0.1-52b --steps 50
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.launch.steps import make_train_step
from repro.launch.train import TrainOptions, train_with_recovery
from repro.models.model import Model
from repro.optim import adamw


def learnable_demo(arch: str, steps: int) -> None:
    """Loss-collapse demo on periodic data (next token fully predictable)."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, weight_decay=0.0)
    params = model.init(jax.random.key(0))
    state = adamw.init_state(opt_cfg, params)
    base = (jnp.arange(65, dtype=jnp.int32) * 7) % cfg.vocab
    toks = jnp.tile(base[None], (8, 1))
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    step = jax.jit(make_train_step(model, opt_cfg))
    for i in range(steps):
        params, state, metrics = step(params, state, batch)
        if i % max(1, steps // 10) == 0 or i == steps - 1:
            print(f"step {i:4d}  ce {float(metrics['ce']):.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):.2f}")
    print(f"final ce {float(metrics['ce']):.4f} (random floor "
          f"{jnp.log(jnp.asarray(float(cfg.vocab))):.2f})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-loop", action="store_true",
                    help="use the fault-tolerant production loop instead")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_tiny")
    args = ap.parse_args()

    if args.full_loop:
        cfg = get_config(args.arch).reduced()
        out = train_with_recovery(cfg, TrainOptions(
            steps=args.steps, batch=8, seq=64, ckpt_dir=args.ckpt_dir,
            ckpt_every=50, log_every=20,
        ))
        print("final step", out["final_step"])
    else:
        learnable_demo(args.arch, args.steps)


if __name__ == "__main__":
    main()
