"""Tuna vs dynamic tuning on this machine's CPU — the paper's experiment you
can reproduce locally: static ranking quality (Fig. 3/4) + compile-time
speedup (Table II) on a real measurable schedule space.

    PYTHONPATH=src:. python examples/tune_operator.py --size 384 --configs 16
"""
import argparse

from benchmarks.compile_time import compile_time_comparison
from benchmarks.topk_ratio import topk_ratio_matmul


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=384)
    ap.add_argument("--configs", type=int, default=16)
    ap.add_argument("--db", default=None,
                    help="repro.tuna schedule DB to read/write")
    args = ap.parse_args()
    n = args.size

    print(f"== top-k performance ratio (matmul {n}^3, "
          f"{args.configs} candidate schedules) ==")
    res = topk_ratio_matmul(n, n, n, n_configs=args.configs, ks=(5, 10),
                            db=args.db)
    for k, v in res.items():
        print(f"  {k}: {v:.4f}" if isinstance(v, float) else f"  {k}: {v}")

    print("== compile time: static analysis vs measure-everything ==")
    ct = compile_time_comparison(n, n, n, n_configs=args.configs)
    print(f"  static  {ct['static_s']:.2f}s   dynamic {ct['dynamic_s']:.2f}s "
          f"  speedup {ct['speedup']:.0f}x")
    print(f"  extrapolated full-space ({ct['full_space']} configs) cost: "
          f"${ct['static_cost_usd_full_space']:.2f} vs "
          f"${ct['dynamic_cost_usd_full_space']:.2f}")


if __name__ == "__main__":
    main()
