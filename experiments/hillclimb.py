"""§Perf hillclimb driver: hypothesis → change → re-lower → record.

Three pairs (picked per the §Perf rules from the baseline table):
  * xlstm-1.3b  × train_4k    — worst roofline fraction / most collective-
    bound (136 s of collectives: replicated-mixer grad all-reduces × accum)
  * qwen3-moe   × train_4k    — largest-scale collective-bound cell (FSDP
    gather + grad reduce per micro)
  * jamba-52b   × prefill_32k — hybrid, paper-representative (distributed-
    level Tuna tunes SP/chunk schedule), also the worst-memory cell

Each variant's record lands in experiments/perf/<pair>.json; EXPERIMENTS.md
§Perf narrates the hypothesis/result pairs from these artifacts. The winning
variant per cell is also persisted to the repro.tuna schedule DB (op
``cell[arch=...,shape=...]``, score = roofline step lower bound) so later
runs start from the known-best knobs instead of the baseline.

    PYTHONPATH=src:. python experiments/hillclimb.py [--pair xlstm_train]
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.launch.dryrun import run_cell  # noqa: E402  (sets XLA_FLAGS first)
from repro.core.tuner import resolve_db  # noqa: E402
from repro.tuna.db import ScheduleDatabase, ScheduleRecord  # noqa: E402
from benchmarks.roofline import structural_terms  # noqa: E402

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "perf")
DEFAULT_DB = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "schedule_db.jsonl")

PAIRS = {
    "xlstm_train": dict(
        arch="xlstm_13b", shape="train_4k",
        variants=[
            ("baseline", {}),
            # H1: collectives ∝ accum (per-micro grad reduce of replicated
            # mixers); activations are tiny (d=2048) so accum can drop 16x
            ("accum_4", {"accum_steps": 4}),
            ("accum_1", {"accum_steps": 1}),
            # H2: int8 grad compression cuts reduce bytes ~4x
            ("accum_1_int8", {"accum_steps": 1, "grad_compression": "int8"}),
            # H3: SP off — xlstm mixers are replicated over model, so seq
            # sharding forces extra gathers? (expect small / refuted)
            ("accum_1_nosp", {"accum_steps": 1, "sp_seq": False}),
            # H4: the accum_1/4 memory blowup is the mLSTM chunk-scan carry
            # saves (64 steps x [B,H,dh,dh] f32); 8x bigger chunks -> 8x
            # fewer carries at O(R^2) intra-chunk cost that still fits
            ("accum_4_chunk512", {"accum_steps": 4, "mlstm_chunk": 512}),
            ("accum_2_chunk512", {"accum_steps": 2, "mlstm_chunk": 512}),
            ("accum_4_chunk256", {"accum_steps": 4, "mlstm_chunk": 256}),
        ],
    ),
    "qwen3_train": dict(
        arch="qwen3_moe_235b_a22b", shape="train_4k",
        variants=[
            ("baseline", {}),
            # H1: halving accum halves FSDP gather+reduce rounds; memory
            # headroom (11.9 GiB temp) should absorb 2x boundaries
            ("accum_8", {"accum_steps": 8}),
            ("accum_4", {"accum_steps": 4}),
            # H2: int8 grads on top of the accum winner
            ("accum_8_int8", {"accum_steps": 8, "grad_compression": "int8"}),
            ("accum_4_int8", {"accum_steps": 4, "grad_compression": "int8"}),
        ],
    ),
    "jamba_prefill": dict(
        arch="jamba_v01_52b", shape="prefill_32k",
        variants=[
            ("baseline", {}),
            # H1: SP drives the big activation gathers; turning it off should
            # shrink all-gather volume but grow per-device activation memory
            ("nosp", {"sp_seq": False}),
            # H2: larger attention KV chunks -> fewer scan steps -> fewer
            # per-chunk collectives on the 4 attention layers
            ("attn_2048", {"attn_chunk": 2048}),
            # H3: larger selective-scan chunks for the 28 mamba layers
            ("ssm_1024", {"ssm_chunk": 1024}),
            ("attn_2048_ssm_1024", {"attn_chunk": 2048, "ssm_chunk": 1024}),
        ],
    ),
}


def run_pair(name: str, db: ScheduleDatabase = None) -> None:
    spec = PAIRS[name]
    os.makedirs(OUT, exist_ok=True)
    cell_sig = f"cell[arch={spec['arch']},shape={spec['shape']}]"
    variants = list(spec["variants"])
    if db is not None:
        warm = db.best(cell_sig, "tpu_v5e")
        if warm is not None:
            print(f"[tuna] warm best for {cell_sig}: {warm.config} "
                  f"(bound {warm.score:.2f}s)")
            # seed the climb from the stored winner: run it first so every
            # later hypothesis is judged against the known best
            knobs = dict(warm.config)
            if all(knobs != dict(v) for _, v in variants):
                variants.insert(0, ("warm_best", knobs))
    results = []
    for vname, variant in variants:
        print(f"=== {name} :: {vname} :: {variant}")
        try:
            rec = run_cell(spec["arch"], spec["shape"], variant=variant,
                           verbose=False)
            terms = structural_terms(spec["arch"], spec["shape"], rec)
            peak = (rec["mem"]["temp_bytes"] + rec["mem"]["argument_bytes"])
            row = {
                "variant": vname, "knobs": variant,
                "compute_s": terms["compute_s"],
                "memory_s": terms["memory_s"],
                "collective_s": terms["collective_s"],
                "bottleneck": terms["bottleneck"],
                "hbm_peak_gib": peak / 2**30,
                "collective_gb_dev": terms["collective_bytes_dev"] / 1e9,
                "step_lower_bound_s": max(terms["compute_s"],
                                          terms["memory_s"],
                                          terms["collective_s"]),
                "roofline_fraction": terms["compute_s"] / max(
                    terms["compute_s"], terms["memory_s"],
                    terms["collective_s"]),
            }
        except Exception as e:  # noqa: BLE001
            row = {"variant": vname, "knobs": variant,
                   "error": f"{type(e).__name__}: {e}"[:300]}
        print(json.dumps(row, indent=None, default=float))
        results.append(row)
    with open(os.path.join(OUT, f"{name}.json"), "w") as f:
        json.dump(results, f, indent=2, default=float)

    ok = [r for r in results if "error" not in r]
    if db is not None and ok:
        winner = min(ok, key=lambda r: r["step_lower_bound_s"])
        db.add(ScheduleRecord(
            op=cell_sig, target="tpu_v5e", config=dict(winner["knobs"]),
            score=winner["step_lower_bound_s"], evaluations=len(ok),
            meta={"strategy": "hillclimb", "model": "roofline",
                  "variant": winner["variant"]},
        ))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=list(PAIRS), default=None)
    ap.add_argument("--db", default=DEFAULT_DB,
                    help="repro.tuna schedule DB path ('' to disable)")
    args = ap.parse_args()
    db = resolve_db(args.db) if args.db else None
    for name in ([args.pair] if args.pair else list(PAIRS)):
        run_pair(name, db=db)


if __name__ == "__main__":
    main()
