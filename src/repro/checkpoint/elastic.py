"""Elastic re-meshing: restore a checkpoint onto a different device count.

Checkpoints are stored unsharded per leaf (store.py), so elasticity is a
matter of recomputing the sharding pytree for the *new* mesh and device_put-
ing. ``reshard_live`` moves an in-memory pytree between meshes (graceful
shrink on failure without round-tripping disk).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.checkpoint import store
from repro.parallel import sharding as sh


def restore_on_mesh(directory: str, tree_like, new_mesh, kind: str = "params",
                    params_like=None, step: Optional[int] = None):
    """kind: 'params' | 'opt' | 'batchlike'."""
    if kind == "params":
        shard = sh.params_sharding(tree_like, new_mesh)
    elif kind == "opt":
        assert params_like is not None
        shard = sh.opt_state_sharding(tree_like, params_like, new_mesh)
    else:
        shard = sh.batch_sharding(tree_like, new_mesh)
    return store.restore(directory, tree_like, step=step, shardings=shard)


def reshard_live(tree, new_shardings):
    """Gather to host then re-place on the new mesh (works across device
    counts; on a real cluster this is the post-failure shrink path)."""
    host = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), host, new_shardings)
