"""Checkpoint store: per-leaf .npy chunks + JSON manifest, atomic, keep-k.

Layout (device-count independent — the elastic path depends on this):

    <dir>/step_000100/
        manifest.json     # tree structure, leaf dtypes/shapes, step, meta
        leaf_00000.npy    # one file per pytree leaf (full, unsharded array)
        ...
    <dir>/LATEST          # atomic pointer file

Writes go to ``step_X.tmp`` then ``os.rename`` — a crash mid-write never
corrupts a visible checkpoint (fault-tolerance test kills mid-save).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _tree_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        out.append((jax.tree_util.keystr(path), leaf))
    return out


def save(directory: str, step: int, tree, meta: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "paths": [p for p, _ in _tree_paths(tree)],
        "leaves": [],
        "meta": meta or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        manifest["leaves"].append(
            {"index": i, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    latest_tmp = os.path.join(directory, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(name)
    os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(directory, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore(directory: str, tree_like, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``tree_like``; optionally device_put
    every leaf with the given shardings pytree (elastic re-shard: the target
    mesh may differ from the one that wrote the checkpoint)."""
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoint under {directory}"
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
    assert len(leaves_like) == len(manifest["leaves"]), (
        len(leaves_like), len(manifest["leaves"]))
    loaded = [
        np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        for i in range(len(leaves_like))
    ]
    tree = jax.tree_util.tree_unflatten(treedef, loaded)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest


def gc_old(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


class AsyncCheckpointer:
    """Non-blocking save: snapshot to host (cheap) then write in background.
    ``wait()`` joins the in-flight write (call before shutdown / next save)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, tree, meta: Optional[Dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def _write():
            self.last_path = save(self.directory, step, host_tree, meta)
            gc_old(self.directory, self.keep)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
