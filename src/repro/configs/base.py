"""Architecture configuration schema + registry.

One ``ArchConfig`` per assigned architecture lives in ``configs/<id>.py``;
``get_config(name)`` resolves them. ``reduced()`` derives the CPU smoke-test
variant (same family/block pattern, tiny dims).
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_expert: int  # expert FFN hidden dim
    shared_expert: bool = False  # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None  # default d_model // n_heads

    activation: str = "swiglu"  # swiglu | sq_relu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- heterogeneous block pattern ---------------------------------
    # mixer kind for layer i: "attention" unless hybrid/ssm patterns below
    attn_every: int = 1  # hybrid: attention iff i % attn_every == attn_offset
    attn_offset: int = 0
    default_mixer: str = "attention"  # what non-attention slots use
    slstm_every: int = 0  # xlstm: sLSTM iff slstm_every and i % it == offset
    slstm_offset: int = 7
    moe: Optional[MoESpec] = None
    moe_every: int = 1  # MoE MLP iff i % moe_every == moe_offset
    moe_offset: int = 0

    # --- encoder/decoder & modality frontends -------------------------
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    frontend: Optional[str] = None  # audio | vision (STUB: embeddings given)
    n_frontend_tokens: int = 0

    # --- SSM internals -------------------------------------------------
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256  # selective-scan chunk (Tuna-tunable)
    mlstm_chunk: int = 64
    attn_chunk: int = 512  # chunked-attention KV block (Tuna-tunable)

    # --- dtypes / numerics ---------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat_stack: bool = True  # per-layer-group remat in apply_stack

    # -------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def period(self) -> int:
        """Block-pattern period for scan grouping (layers stacked per kind)."""
        p = 1
        if self.attn_every > 1:
            p = math.lcm(p, self.attn_every)
        if self.slstm_every > 1:
            p = math.lcm(p, self.slstm_every)
        if self.moe is not None and self.moe_every > 1:
            p = math.lcm(p, self.moe_every)
        return p

    @property
    def sub_quadratic(self) -> bool:
        """True if per-token decode cost is O(1)-ish in context (SSM/hybrid):
        eligible for the long_500k shape."""
        return self.family in ("hybrid", "ssm")

    def mixer_kind(self, i: int) -> str:
        if self.slstm_every > 1:
            return "slstm" if i % self.slstm_every == self.slstm_offset else "mlstm"
        if self.attn_every > 1:
            return (
                "attention"
                if i % self.attn_every == self.attn_offset
                else self.default_mixer
            )
        return self.default_mixer

    def mlp_kind(self, i: int) -> str:
        if self.d_ff == 0 and self.moe is None:
            return "none"
        if self.moe is not None and i % self.moe_every == self.moe_offset:
            return "moe"
        return "dense"

    def pattern(self) -> Tuple[Tuple[str, str], ...]:
        """(mixer, mlp) for one period."""
        return tuple(
            (self.mixer_kind(i), self.mlp_kind(i)) for i in range(self.period)
        )

    def param_count(self) -> int:
        """Total parameters (for 6·N·D roofline bookkeeping)."""
        return _count_params(self)

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top_k + shared experts only)."""
        return _count_params(self, active_only=True)

    def jnp_param_dtype(self):
        return getattr(jnp, self.param_dtype)

    def jnp_compute_dtype(self):
        return getattr(jnp, self.compute_dtype)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_expert=64,
                capacity_factor=2.0,
            )
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2 * self.period,
            n_encoder_layers=2 if self.encoder_decoder else 0,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_head=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            moe=moe,
            n_frontend_tokens=8 if self.frontend else 0,
            ssm_state=8,
            mlstm_chunk=8,
            param_dtype="float32",
            compute_dtype="float32",
        )


def _mixer_params(cfg: ArchConfig, kind: str) -> int:
    d = cfg.d_model
    hd = cfg.head_dim
    if kind == "attention":
        qkv = d * (cfg.n_heads * hd + 2 * cfg.n_kv_heads * hd)
        return qkv + cfg.n_heads * hd * d
    if kind == "mamba":
        di = cfg.ssm_expand * d
        return (
            d * 2 * di  # in_proj (x, z)
            + di * cfg.ssm_conv  # depthwise conv
            + di * (2 * cfg.ssm_state + 1)  # W_B, W_C, W_dt(rank-1ish)
            + d * di // 16  # dt projection (low rank)
            + di * cfg.ssm_state  # A_log
            + di  # D skip
            + di * d  # out_proj
        )
    if kind == "mlstm":
        di = 2 * d
        h = cfg.n_heads
        return d * 3 * di + 3 * d * h + di * d  # qkv, gates(i,f,o per head), out
    if kind == "slstm":
        h = cfg.n_heads
        dh = cfg.d_model // h
        return 4 * d * d + 4 * h * dh * dh + d * d  # in gates, recurrent, out
    return 0


def _mlp_params(cfg: ArchConfig, kind: str) -> int:
    d = cfg.d_model
    if kind == "dense":
        mult = 3 if cfg.activation in ("swiglu", "geglu") else 2
        return mult * d * cfg.d_ff
    if kind == "moe":
        moe = cfg.moe
        mult = 3 if cfg.activation in ("swiglu", "geglu") else 2
        per_expert = mult * d * moe.d_expert
        total = moe.n_experts * per_expert + d * moe.n_experts  # + router
        if moe.shared_expert:
            total += per_expert
        return total
    return 0


def _mlp_active_params(cfg: ArchConfig, kind: str) -> int:
    if kind != "moe":
        return _mlp_params(cfg, kind)
    moe = cfg.moe
    mult = 3 if cfg.activation in ("swiglu", "geglu") else 2
    per_expert = mult * cfg.d_model * moe.d_expert
    active = moe.top_k * per_expert + cfg.d_model * moe.n_experts
    if moe.shared_expert:
        active += per_expert
    return active


def _count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    total = cfg.vocab * cfg.d_model  # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab * cfg.d_model
    layers = []
    for i in range(cfg.n_layers):
        layers.append((cfg.mixer_kind(i), cfg.mlp_kind(i)))
    for mixer, mlp in layers:
        total += _mixer_params(cfg, mixer)
        total += (
            _mlp_active_params(cfg, mlp) if active_only else _mlp_params(cfg, mlp)
        )
        total += 2 * cfg.d_model  # norms
    if cfg.encoder_decoder:
        for _ in range(cfg.n_encoder_layers):
            total += _mixer_params(cfg, "attention") + _mlp_params(cfg, "dense")
            total += 2 * cfg.d_model
        total += cfg.n_layers * (_mixer_params(cfg, "attention") + cfg.d_model)
    return total


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "jamba_v01_52b",
    "nemotron_4_15b",
    "qwen25_14b",
    "stablelm_3b",
    "yi_6b",
    "qwen3_moe_235b_a22b",
    "llama4_maverick_400b_a17b",
    "whisper_large_v3",
    "internvl2_1b",
    "xlstm_13b",
)

_ALIASES = {
    "jamba-v0.1-52b": "jamba_v01_52b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen2.5-14b": "qwen25_14b",
    "stablelm-3b": "stablelm_3b",
    "yi-6b": "yi_6b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "whisper-large-v3": "whisper_large_v3",
    "internvl2-1b": "internvl2_1b",
    "xlstm-1.3b": "xlstm_13b",
}


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", ""))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
