"""internvl2-1b [vlm] — Qwen2-0.5B-class language backbone; InternViT
frontend is a STUB (input_specs provides patch embeddings)
[arXiv:2404.16821]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    frontend="vision",
    n_frontend_tokens=256,  # 448x448 / 14 patch / pixel-shuffle 4
)
