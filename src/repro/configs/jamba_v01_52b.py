"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
every 2nd layer [arXiv:2403.19887]."""
from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    activation="swiglu",
    attn_every=8,  # 1 attention : 7 mamba
    attn_offset=4,
    default_mixer="mamba",
    moe=MoESpec(n_experts=16, top_k=2, d_expert=14336),
    moe_every=2,
    moe_offset=1,
)
