"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + shared expert,
dense/MoE interleave (every 2nd layer), early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E family]."""
from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    activation="swiglu",
    rope_theta=5e5,
    moe=MoESpec(n_experts=128, top_k=1, d_expert=8192, shared_expert=True),
    moe_every=2,
    moe_offset=1,
)
