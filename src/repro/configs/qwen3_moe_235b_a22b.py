"""qwen3-moe-235b-a22b [moe] — 94L, 128 experts top-8, expert d_ff=1536,
head_dim 128 [hf:Qwen/Qwen3-30B-A3B family]."""
from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_head=128,
    d_ff=0,  # every MLP is MoE
    vocab=151936,
    activation="swiglu",
    rope_theta=1e6,
    moe=MoESpec(n_experts=128, top_k=8, d_expert=1536),
    moe_every=1,
)
