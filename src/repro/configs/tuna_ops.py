"""The paper's own operator benchmark set (§V.B): the single-operator
workloads Tuna tunes, with the shapes used by our measured CPU validation
and the TPU static tuning demos. benchmarks/topk_ratio.py consumes these."""
from repro.core.spaces import (
    BatchMatmulSpace,
    Conv2dSpace,
    DepthwiseConv2dSpace,
    MatmulSpace,
)

# name -> factory(target_kind) (paper: conv2d, conv2d_winograd,
# depthwise_conv2d, batch_matrix_multiplication; winograd is represented by
# its GEMM core — the paper skips it on CPU targets too)
OPERATORS = {
    "dense_256": lambda kind="cpu": MatmulSpace(256, 256, 256, 4, kind),
    "dense_512": lambda kind="cpu": MatmulSpace(512, 512, 512, 4, kind),
    "conv2d": lambda kind="cpu": Conv2dSpace(1, 14, 14, 256, 256, 3, 3, 4,
                                             kind),
    "depthwise_conv2d": lambda kind="cpu": DepthwiseConv2dSpace(
        1, 28, 28, 128, 3, 3, 4, kind),
    "batch_matmul": lambda kind="cpu": BatchMatmulSpace(8, 128, 128, 64, 4,
                                                        kind),
    # bf16 TPU matmul shapes the kernel block-spec picker asks for at trace
    # time — tuning these warms the DB that tuned_matmul_blocks consults
    "matmul_1024_bf16": lambda kind="tpu": MatmulSpace(1024, 1024, 1024, 2,
                                                       kind),
    "matmul_2048_bf16": lambda kind="tpu": MatmulSpace(2048, 2048, 2048, 2,
                                                       kind),
    "matmul_4096_bf16": lambda kind="tpu": MatmulSpace(4096, 4096, 4096, 2,
                                                       kind),
}

# small fixed subset exercised by `python -m repro.tuna tune --smoke`
# (CI cold-start check: one matmul + one batched space, seconds to tune)
SMOKE_OPERATORS = ("dense_256", "batch_matmul")
