"""Tunable operator presets, enumerated from the declarative registry.

Historically this file hand-listed the paper's §V.B operator benchmark set
against the four ``Space`` subclasses. It is now a thin enumeration of
``repro.core.op_registry``: every registered :class:`OpDef` preset becomes a
named ``OPERATORS`` entry (``name -> factory(target_kind)``), so registering
a new op family (see ``repro.core.zoo``) automatically widens the tuning
matrix, the fleet job grid and the benchmarks."""
from typing import Callable, Dict

from repro.core import op_registry
from repro.core.op_registry import Space


def _factory(family: str, preset: op_registry.Preset,
             ) -> Callable[..., Space]:
    def make(kind: str = preset.kind) -> Space:
        return op_registry.make_space(family, preset.attrs, kind)
    make.__name__ = f"make_{family}"
    return make


# name -> factory(target_kind), in registry order: the paper set first
# (matmul/conv/depthwise/bmm — winograd is represented by its GEMM core; the
# paper skips it on CPU targets too), then the model-zoo families.
OPERATORS: Dict[str, Callable[..., Space]] = {
    name: _factory(family, preset)
    for name, (family, preset) in op_registry.all_presets().items()
}

# small fixed subset exercised by `python -m repro.tuna tune --smoke`
# (CI cold-start check: one matmul + one batched space, seconds to tune)
SMOKE_OPERATORS = ("dense_256", "batch_matmul")

# one preset per model-zoo family (CI zoo-smoke tunes these on all targets)
ZOO_OPERATORS = ("moe_dispatch", "ssm_scan", "mlstm_chunk", "flash_gqa")
