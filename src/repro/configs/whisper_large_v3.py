"""whisper-large-v3 [audio] — enc-dec transformer backbone; the conv/mel
frontend is a STUB (input_specs provides precomputed frame embeddings)
[arXiv:2212.04356]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,  # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    activation="gelu",
    norm="layernorm",
    encoder_decoder=True,
    n_encoder_layers=32,
    frontend="audio",
    n_frontend_tokens=1500,  # 30 s of mel frames after conv stride 2
)
