"""xlstm-1.3b [ssm] — mLSTM/sLSTM 7:1 block stack, no FFN (mLSTM up-proj
carries the capacity) [arXiv:2405.04517]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    default_mixer="mlstm",
    slstm_every=8,
    slstm_offset=7,
    norm="layernorm",
)
