"""Tuna core — static analysis optimization of tensor programs (the paper's
contribution), adapted to TPU as described in DESIGN.md §2.

Pipeline:  TIR (tir) ──► VISA lowering (visa) ──► Alg.1 joint counting
(instcount) + Alg.2 locality (locality) + ILP scheduling (ilp) ──► linear
cost model (cost_model) ──► ES search (es) over schedule spaces (spaces),
driven by the tuner (tuner). ``hlo_features`` + ``sharding_tuner`` apply the
same methodology to jit-lowered HLO at the distributed level.
"""
from repro.core.tir import Access, Compute, LinExpr, Loop, Program, TensorDecl
from repro.core.locality import analyze_locality, LocalityReport
from repro.core.visa import lower_program, VisaProgram
from repro.core.instcount import count_instructions, match_loops, InstReport
from repro.core.ilp import analyze_ilp, IlpReport
from repro.core.cost_model import (
    COST_MODEL_VERSION,
    Features,
    ScheduleMeta,
    coefficients,
    evaluate,
    extract_features,
    score,
)
from repro.core.es import evolve, ESResult
from repro.core.spaces import (
    BatchMatmulSpace,
    Conv2dSpace,
    DepthwiseConv2dSpace,
    MatmulSpace,
    Space,
)
from repro.core.tuner import (
    TuneResult,
    best_schedule,
    rank_space,
    set_default_db,
    tune,
    tuned_matmul_blocks,
)
