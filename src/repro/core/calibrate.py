"""One-shot coefficient calibration (paper §III: "coefficients a_0..a_n are
generated for each hardware architecture through hardware instruction latency
and empirical profiling data").

A small probe set (one shape, ~16 schedules) is measured ONCE per host
architecture; a non-negative least-squares fit maps static features to
seconds. The fitted coefficients are then reused for *every* operator and
shape on that architecture — search itself stays fully static (this mirrors
the paper's transferability claim across micro-architectures that share a
SIMD ISA). Results are cached as JSON next to the experiments.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import cost_model
from repro.core.spaces import MatmulSpace
from repro.hw import get_target

FEATURES = ("ilp_cycles", "movement_bytes", "arith_ops", "ldst_ops",
            "dispatch_calls")


def _nnls(A: np.ndarray, y: np.ndarray, iters: int = 2000) -> np.ndarray:
    """Projected-gradient NNLS (no scipy in this environment)."""
    x = np.zeros(A.shape[1])
    At = A.T
    L = np.linalg.norm(A, 2) ** 2 + 1e-12
    for _ in range(iters):
        x = np.maximum(0.0, x - (At @ (A @ x - y)) / L)
    return x


def fit_cpu_coefficients(
    probe: Tuple[int, int, int] = (256, 256, 256),
    n_configs: int = 16,
    iters: int = 3,
    seed: int = 123,  # disjoint from the evaluation sample seeds
) -> Dict[str, float]:
    """Measure a probe set on the host CPU, fit per-feature seconds."""
    import jax.numpy as jnp

    from benchmarks.measure import measure_config
    from benchmarks.topk_ratio import sample_space

    target = get_target("cpu_avx2")
    M, N, K = probe
    space = MatmulSpace(M, N, K, 4, target_kind="cpu")
    cfgs = sample_space(space, n_configs, seed)

    rows: List[List[float]] = []
    ys: List[float] = []
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    for cfg in cfgs:
        prog, meta = space.instantiate(cfg)
        f = cost_model.extract_features(prog, target, meta)
        rows.append([getattr(f, name) for name in FEATURES] + [1.0])
        ys.append(measure_config(M, N, K, cfg, a, b, iters=iters))

    A = np.asarray(rows)
    y = np.asarray(ys)
    scale = A.max(axis=0)
    scale[scale == 0] = 1.0
    x = _nnls(A / scale, y)
    coef = x / scale
    out = {name: float(c) for name, c in zip(FEATURES, coef)}
    out["intercept"] = float(coef[-1])
    # residual quality
    pred = A @ coef
    ss = 1.0 - np.sum((pred - y) ** 2) / max(np.sum((y - y.mean()) ** 2), 1e-12)
    out["_r2_on_probe"] = float(ss)
    return out


def coeffs_for_scoring(fitted: Dict[str, float]) -> Dict[str, float]:
    """Convert a fit into the cost_model.score coefficient dict."""
    base = dict(
        ilp_cycles=fitted["ilp_cycles"],
        movement_bytes=fitted["movement_bytes"],
        arith_ops=fitted["arith_ops"],
        ldst_ops=fitted["ldst_ops"],
        dispatch_calls=fitted.get("dispatch_calls", 0.0),
        unhidden_dma_cycles=0.0,
        alignment_waste=1e-6,
        occupancy_penalty=1e-6,
        vmem_overflow=1.0,
        parallel_extent=0.0,
    )
    return base


_CACHE_PATH = os.path.join("experiments", "cpu_calibration.json")


def cached_cpu_coeffs(path: str = _CACHE_PATH,
                      refit: bool = False) -> Optional[Dict[str, float]]:
    if not refit and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    try:
        fitted = fit_cpu_coefficients()
    except Exception:  # noqa: BLE001 — measurement unavailable (no jit?)
        return None
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(fitted, f, indent=2)
    return fitted
