"""Hardware-related analytical cost model — paper §III, Eq. (2).

``score = a0*f0 + a1*f1 + ... + an*fn`` over features extracted *statically*
from the two-level analysis (TIR + VISA). Coefficients are derived from the
target's datasheet constants (instruction inverse-throughputs, clock, HBM
bandwidth) — no measurement on the target device is involved, which is the
paper's central constraint. Lower score = predicted faster.

Feature set (TPU column of DESIGN.md §2's adaptation table):

  f0  ilp_cycles          VLIW/OoO scheduler makespan (Σ block × execs)
  f1  movement_bytes      Alg. 2 locality model (fast-mem boundary traffic)
  f2  unhidden_dma_cycles DMA not overlapped with compute (latency hiding)
  f3  mxu_ops / simd_fma  significant arithmetic instruction count
  f4  ldst_ops            significant data-movement instruction count
  f5  alignment_waste     tail-lane / MXU-padding waste fraction
  f6  occupancy_penalty   grid-vs-cores underutilisation (SM-occupancy analogue)
  f7  vmem_overflow       hard penalty: working set exceeds fast memory
  f8  dispatch_calls      grid/block-loop iterations — per-tile dispatch
                          overhead (dominant for XLA:CPU block executors;
                          small but real Pallas grid-step cost on TPU)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import ilp as ilp_mod
from repro.core import instcount as ic_mod
from repro.core import visa as visa_mod
from repro.core.locality import analyze_locality
from repro.core.tir import Program
from repro.hw.target import HardwareTarget

# Version tag of the feature extractor + coefficient derivation. Schedule
# records persisted by ``repro.tuna`` are keyed by this string: bump it
# whenever ``extract_features``/``coefficients``/``score`` change meaning, so
# stored schedules are re-derived instead of silently reused with stale
# scores (tests/test_tuna.py pins the cm1 feature vector as a golden).
COST_MODEL_VERSION = "cm1"


@dataclasses.dataclass(frozen=True)
class Features:
    ilp_cycles: float
    movement_bytes: float
    unhidden_dma_cycles: float
    arith_ops: float
    ldst_ops: float
    alignment_waste: float
    occupancy_penalty: float
    vmem_overflow: float
    parallel_extent: int
    dispatch_calls: float = 0.0

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class ScheduleMeta:
    """Side information the schedule instantiation passes to the model."""

    grid_size: int = 1
    double_buffer: bool = False
    parallel_extent: int = 1
    vmem_tile_bytes: int = 0  # per-grid-step working set claimed in fast mem


def extract_features(
    program: Program, target: HardwareTarget, meta: Optional[ScheduleMeta] = None
) -> Features:
    meta = meta or ScheduleMeta()
    visa = visa_mod.lower_program(program, target)
    counts = ic_mod.count_instructions(program, visa)
    ilp = ilp_mod.analyze_ilp(visa, target, double_buffer=meta.double_buffer)
    loc = analyze_locality(program, target.fast_mem_bytes)

    arith = sum(
        counts.counts.get(op, 0.0)
        for op in ("mxu.matmul", "vpu.fma", "vpu.add", "vpu.mul", "simd.fma",
                   "simd.add", "simd.mul")
    )
    ldst = sum(
        counts.counts.get(op, 0.0)
        for op in ("vpu.load", "vpu.store", "simd.load", "simd.store",
                   "simd.broadcast")
    )
    unhidden = ilp.dma_cycles * (1.0 - ilp.hidden_dma_frac)

    # SM-occupancy analogue: penalise grids that underfill or tail-wave cores
    cores = target.num_cores
    g = max(1, meta.grid_size)
    if g < cores:
        occupancy = (cores - g) / cores
    else:
        full, tail = divmod(g, cores)
        occupancy = 0.0 if tail == 0 else (1.0 - tail / cores) / (full + 1)

    buffers = 2 if meta.double_buffer else 1
    overflow = max(0.0, meta.vmem_tile_bytes * buffers - target.fast_mem_bytes)

    return Features(
        ilp_cycles=ilp.total_cycles,
        movement_bytes=loc.movement_bytes,
        unhidden_dma_cycles=unhidden,
        arith_ops=arith,
        ldst_ops=ldst,
        alignment_waste=counts.wasted_lane_frac,
        occupancy_penalty=occupancy,
        vmem_overflow=overflow,
        parallel_extent=meta.parallel_extent,
        dispatch_calls=float(meta.grid_size),
    )


def coefficients(target: HardwareTarget) -> Dict[str, float]:
    """Per-architecture coefficients from hardware constants (paper: derived
    from instruction latency tables; transferable across micro-architectures
    that share the SIMD ISA)."""
    cyc = 1.0 / target.clock_hz
    return {
        "ilp_cycles": cyc,
        "movement_bytes": 1.0 / target.hbm_bandwidth,
        "unhidden_dma_cycles": 0.5 * cyc,  # partially re-counted vs ILP term
        "arith_ops": 0.0,  # subsumed by ILP makespan; kept for calibration
        "ldst_ops": 0.0,
        "alignment_waste": 1e-4,  # dimensionless nudge between near-ties
        "occupancy_penalty": 1e-4,
        "vmem_overflow": 1.0,  # bytes over fast mem: effectively -inf fitness
        "parallel_extent": 0.0,
        # per-grid-step dispatch: ~scalar-core bookkeeping on TPU; the CPU
        # coefficient is calibrated (block dispatch dominates XLA:CPU loops)
        "dispatch_calls": 20.0 / target.clock_hz,
    }


def score(features: Features, target: HardwareTarget,
          coeffs: Optional[Dict[str, float]] = None) -> float:
    """Eq. (2): linear combination; divided by exploitable core parallelism
    (thread-level-parallelism term of the paper's CPU model)."""
    coeffs = coeffs or coefficients(target)
    f = features.as_dict()
    par = min(target.num_cores, max(1, features.parallel_extent))
    time_like = (
        f["ilp_cycles"] * coeffs["ilp_cycles"]
        + f["unhidden_dma_cycles"] * coeffs["unhidden_dma_cycles"]
        + f["arith_ops"] * coeffs["arith_ops"]
        + f["ldst_ops"] * coeffs["ldst_ops"]
        + f["dispatch_calls"] * coeffs.get("dispatch_calls", 0.0)
    ) / par + f["movement_bytes"] * coeffs["movement_bytes"]
    penalty = (
        f["alignment_waste"] * coeffs["alignment_waste"]
        + f["occupancy_penalty"] * coeffs["occupancy_penalty"]
        + f["vmem_overflow"] * coeffs["vmem_overflow"]
    )
    return time_like * (1.0 + f["alignment_waste"]) + penalty


def evaluate(program: Program, target: HardwareTarget,
             meta: Optional[ScheduleMeta] = None,
             coeffs: Optional[Dict[str, float]] = None) -> float:
    return score(extract_features(program, target, meta), target, coeffs)
