"""Evolution Strategies — paper §IV, Algorithm 4.

Treats schedule selection as black-box optimization over continuous θ:

    sample ε_1..ε_n ~ N(0, I)
    F_i = F(θ_t + σ ε_i)
    θ_{t+1} = θ_t + α · 1/(nσ) · Σ F_i ε_i

F is *maximised* (we pass negative cost). Population evaluations are
dispatched to a thread pool — the paper's multi-threaded search: static
analysis, unlike on-device measurement, parallelises freely.

Deviations from the bare algorithm (DESIGN.md §7.3): rank-shaped fitness
(standard ES variance reduction), mirrored sampling, and geometric σ decay in
place of the paper's outer black-box tuning of (α, σ).
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class ESResult:
    best_theta: np.ndarray
    best_fitness: float
    evaluations: int
    history: List[float]  # best-so-far per iteration


def _rank_shape(fs: np.ndarray) -> np.ndarray:
    """Centered rank transform in [-0.5, 0.5]."""
    ranks = np.empty_like(fs)
    ranks[np.argsort(fs)] = np.arange(len(fs))
    if len(fs) <= 1:
        return np.zeros_like(fs)
    return ranks / (len(fs) - 1) - 0.5


def evolve(
    fitness: Callable[[np.ndarray], float],
    dim: int,
    iterations: int = 20,
    population: int = 16,
    alpha: float = 1.0,
    sigma: float = 0.7,
    sigma_decay: float = 0.97,
    seed: int = 0,
    theta0: Optional[np.ndarray] = None,
    workers: int = 8,
    mirrored: bool = True,
) -> ESResult:
    rng = np.random.default_rng(seed)
    theta = np.zeros(dim) if theta0 is None else np.asarray(theta0, float).copy()

    best_theta = theta.copy()
    best_f = -np.inf
    history: List[float] = []
    evals = 0

    pool = cf.ThreadPoolExecutor(max_workers=max(1, workers))
    try:
        for _t in range(iterations):
            half = max(1, population // 2)
            eps = rng.standard_normal((half, dim))
            if mirrored:
                eps = np.concatenate([eps, -eps], axis=0)
            cands = theta[None, :] + sigma * eps
            fs = np.fromiter(
                pool.map(fitness, [c for c in cands]), dtype=float, count=len(cands)
            )
            evals += len(cands)

            i_best = int(np.argmax(fs))
            if fs[i_best] > best_f:
                best_f = float(fs[i_best])
                best_theta = cands[i_best].copy()
            history.append(best_f)

            shaped = _rank_shape(fs)
            theta = theta + alpha / (len(cands) * sigma) * (shaped @ eps)
            sigma = max(0.05, sigma * sigma_decay)
    finally:
        pool.shutdown(wait=False)

    return ESResult(best_theta, best_f, evals, history)
