"""Static features from XLA HLO text — the distributed-level "low-level code".

The paper parses generated assembly/PTX because that is where the real
instruction mix lives. At the *graph/distributed* level our generated code is
the optimized HLO from ``jax.jit(...).lower(...).compile()`` — obtainable on
any host with zero target hardware (the cross-compilation setting). From it
we extract:

* per-kind **collective statistics**: op counts, operand bytes (the §Roofline
  "collective term" numerator) and modeled per-device link bytes (ring
  algorithm: all-reduce moves 2·(s−1)/s·bytes, all-gather (s−1)/s, ...);
* layout-change ops (transpose/copy/bitcast-convert) and fusion counts —
  the "redundant reshape between sharded ops" smell the perf loop hunts;
* HLO flops/bytes via ``compiled.cost_analysis()`` are read separately by the
  roofline module; this parser is purely textual so it also works on
  ``lowered.as_text()`` (pre-optimization StableHLO is NOT supported — feed
  post-compile HLO).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Mapping, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(text: str) -> float:
    """Sum of bytes over every shape literal in ``text``."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    operand_bytes: Dict[str, float]  # per-device operand payload, by kind
    link_bytes: Dict[str, float]  # modeled ring-traffic per device, by kind

    @property
    def total_operand_bytes(self) -> float:
        return sum(self.operand_bytes.values())

    @property
    def total_link_bytes(self) -> float:
        return sum(self.link_bytes.values())


@dataclasses.dataclass
class HloFeatures:
    collectives: CollectiveStats
    n_fusions: int
    n_dots: int  # dot/convolution ops (post-fusion)
    n_layout_ops: int  # transpose/copy/bitcast — layout-change overhead
    n_while: int  # scan loops surviving in HLO


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    op_bytes: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_KINDS}
    link: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_KINDS}

    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs_rhs = stripped.split("=", 1)
        rhs = lhs_rhs[1].lstrip()
        kind = None
        for k in COLLECTIVE_KINDS:
            # match `f32[..] all-reduce(` and async `all-reduce-start(`;
            # skip `-done` halves (payload already counted at -start)
            if re.search(rf"\b{k}(-start)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        # result shape(s): everything left of the op name on the rhs
        result_part = rhs.split(kind)[0]
        result_bytes = _shape_bytes(result_part)
        if result_bytes == 0.0:
            continue
        s = max(1, _group_size(stripped))
        counts[kind] += 1
        if kind == "all-gather":
            operand = result_bytes / s
            lk = result_bytes * (s - 1) / s
        elif kind == "reduce-scatter":
            operand = result_bytes * s
            lk = result_bytes * (s - 1)
        elif kind == "all-reduce":
            operand = result_bytes
            lk = 2.0 * result_bytes * (s - 1) / s
        elif kind == "all-to-all":
            operand = result_bytes
            lk = result_bytes * (s - 1) / s
        else:  # collective-permute
            operand = result_bytes
            lk = result_bytes
        op_bytes[kind] += operand
        link[kind] += lk
    return CollectiveStats(counts=counts, operand_bytes=op_bytes, link_bytes=link)


def parse_hlo(hlo_text: str) -> HloFeatures:
    n_fusion = len(re.findall(r"\bfusion\(", hlo_text))
    n_dots = len(re.findall(r"\b(?:dot|convolution)\(", hlo_text))
    n_layout = len(re.findall(r"\b(?:transpose|copy|bitcast-convert)\(", hlo_text))
    n_while = len(re.findall(r"\bwhile\(", hlo_text))
    return HloFeatures(
        collectives=parse_collectives(hlo_text),
        n_fusions=n_fusion,
        n_dots=n_dots,
        n_layout_ops=n_layout,
        n_while=n_while,
    )


def collective_bytes(hlo_text: str) -> float:
    """§Roofline numerator: summed per-device collective operand bytes."""
    return parse_collectives(hlo_text).total_operand_bytes


# ---------------------------------------------------------------------------
# while-loop trip scaling
# ---------------------------------------------------------------------------
# XLA's cost/byte accounting (and a naive text parse) counts a while body
# ONCE — a scanned 94-layer stack or a 16-step grad-accum loop under-reports
# its collectives by the trip count. We recover trip counts from each while's
# condition computation (the loop counter is compared against an s32
# constant) and propagate multipliers through nested loops.

_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{")
_WHILE_RE = re.compile(
    r"while\([^)]*\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)"
)
_S32_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    current: str | None = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if current is None:
            m = _COMP_HEADER_RE.match(line.strip())
            if m and line.strip().endswith("{"):
                current = m.group(1)
                comps[current] = []
        else:
            if line.strip() == "}":
                current = None
            else:
                comps[current].append(line)
    return comps


def loop_scaled_collectives(hlo_text: str, entry_hint: str = "") -> CollectiveStats:
    """Collective stats with while-body contributions multiplied by their
    recovered trip counts (nested loops compose multiplicatively)."""
    comps = _split_computations(hlo_text)

    # per-computation raw stats + while edges
    raw: Dict[str, CollectiveStats] = {}
    edges: Dict[str, List[Tuple[str, str]]] = {}  # comp -> [(cond, body)]
    for name, lines in comps.items():
        raw[name] = parse_collectives("\n".join(lines))
        edges[name] = [
            (m.group(1), m.group(2))
            for line in lines
            for m in [_WHILE_RE.search(line)]
            if m
        ]

    def trip_of(cond_name: str) -> int:
        lines = comps.get(cond_name, [])
        consts = [int(m.group(1)) for line in lines
                  for m in _S32_CONST_RE.finditer(line)]
        return max(consts) if consts else 1

    # multipliers: entry computations = those never referenced as a body
    bodies = {b for es in edges.values() for _, b in es}
    mult: Dict[str, float] = {n: 0.0 for n in comps}
    for n in comps:
        if n not in bodies:
            mult[n] = 1.0

    # propagate (few levels of nesting; iterate to fixpoint)
    for _ in range(8):
        changed = False
        for n, es in edges.items():
            for cond, body in es:
                new = mult.get(n, 0.0) * trip_of(cond)
                if new > mult.get(body, 0.0):
                    mult[body] = new
                    changed = True
        if not changed:
            break

    counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    op_bytes: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_KINDS}
    link: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_KINDS}
    for n, st in raw.items():
        m = mult.get(n, 0.0)
        if m <= 0:
            continue
        for k in COLLECTIVE_KINDS:
            counts[k] += int(st.counts[k] * m)
            op_bytes[k] += st.operand_bytes[k] * m
            link[k] += st.link_bytes[k] * m
    return CollectiveStats(counts=counts, operand_bytes=op_bytes, link_bytes=link)
