"""Instruction-level-parallelism model — paper §III-A.3.

A simplified fast out-of-order / VLIW scheduler run over every basic block:

* **data dependency builder** — two dependency graphs: true (RAW) and false
  (WAR + WAW) dependencies, over both registers and memory resources (tensor
  operands of loads/stores/DMAs);
* **instruction scheduler** — list scheduling under structural hazards (per-
  functional-unit issue pipelines with inverse-throughput occupancy + global
  issue width) and data hazards (RAW: consumer starts after producer
  completes; WAR/WAW: the later writer cannot start before the earlier
  instruction has issued).

The block's ILP cost is the makespan; the program cost is
Σ block_makespan × block_executions (paper: "product of ILP cost and number
of executions"). DMA instructions carry byte payloads — their completion
latency includes the bandwidth term, and with ``double_buffer=True`` their
true-dependency edges to same-tensor loads are dropped (the payload was
prefetched during the previous grid step — the TPU latency-hiding analogue of
the paper's GPU warp-latency-hiding feature).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

from repro.core.instcount import LoopSpan, identify_loop_spans
from repro.core.visa import VInstr, VisaProgram
from repro.hw.target import HardwareTarget


@dataclasses.dataclass(frozen=True)
class IlpReport:
    total_cycles: float
    blocks: Tuple[Tuple[int, float, float], ...]  # (start idx, makespan, execs)
    dma_cycles: float  # total cycles DMA units are busy
    compute_cycles: float  # total cycles compute units are busy
    hidden_dma_frac: float  # fraction of DMA busy-time overlapped with compute


def _effective_latency(ins: VInstr, target: HardwareTarget) -> float:
    unit, lat, _ = target.instruction_table[ins.opcode]
    if ins.opcode.startswith("dma."):
        return lat + ins.meta.get("bytes", 0) / target.bytes_per_cycle_hbm
    return lat


def schedule_block(
    instrs: List[VInstr], target: HardwareTarget, double_buffer: bool = False
) -> Tuple[float, float, float]:
    """Returns (makespan, dma_busy, compute_busy) in cycles."""
    table = target.instruction_table
    items = [ins for ins in instrs if ins.opcode in table]
    n = len(items)
    if n == 0:
        return 0.0, 0.0, 0.0

    # ---- data dependency builder -------------------------------------
    true_dep: List[List[int]] = [[] for _ in range(n)]  # RAW: j depends on i
    false_dep: List[List[int]] = [[] for _ in range(n)]  # WAR/WAW
    last_writer: Dict[str, int] = {}
    readers: Dict[str, List[int]] = {}
    last_mem_writer: Dict[str, int] = {}
    mem_readers: Dict[str, List[int]] = {}

    def is_mem_read(ins: VInstr) -> List[str]:
        if ins.opcode in ("vpu.load", "simd.load", "simd.broadcast"):
            return [ins.srcs[0]] if ins.srcs else []
        if ins.opcode == "dma.store":
            return [ins.srcs[0]] if ins.srcs else []
        return []

    def is_mem_write(ins: VInstr) -> List[str]:
        if ins.opcode in ("vpu.store", "simd.store"):
            return [ins.srcs[1]] if len(ins.srcs) > 1 else []
        if ins.opcode == "dma.load" and not double_buffer:
            return [ins.srcs[0]] if ins.srcs else []
        return []

    for j, ins in enumerate(items):
        mem_r = set(is_mem_read(ins))
        mem_w = set(is_mem_write(ins))
        for src in ins.srcs:
            if src in mem_r or src in mem_w:
                continue
            if src in last_writer:
                true_dep[j].append(last_writer[src])
            readers.setdefault(src, []).append(j)
        for t in mem_r:
            if t in last_mem_writer:
                true_dep[j].append(last_mem_writer[t])
            mem_readers.setdefault(t, []).append(j)
        if ins.dest is not None:
            if ins.dest in last_writer:
                false_dep[j].append(last_writer[ins.dest])  # WAW
            for r in readers.get(ins.dest, ()):
                false_dep[j].append(r)  # WAR
            last_writer[ins.dest] = j
            readers[ins.dest] = []
        for t in mem_w:
            if t in last_mem_writer:
                false_dep[j].append(last_mem_writer[t])
            for r in mem_readers.get(t, ()):
                false_dep[j].append(r)
            last_mem_writer[t] = j
            mem_readers[t] = []

    # ---- list scheduler ------------------------------------------------
    # per-unit pipelines: issue_width slots, each busy inv_throughput cycles
    unit_slots: Dict[str, List[float]] = {
        u.name: [0.0] * u.issue_width for u in target.units
    }
    issue_time = [0.0] * n
    finish_time = [0.0] * n
    global_issue: Dict[float, int] = {}

    order = list(range(n))  # program order as priority (list scheduling)
    scheduled = [False] * n
    dma_busy = 0.0
    compute_busy = 0.0
    for j in order:
        ins = items[j]
        unit, lat, inv_tp = table[ins.opcode]
        eff_lat = _effective_latency(ins, target)
        ready = 0.0
        for i in true_dep[j]:
            ready = max(ready, finish_time[i])
        for i in false_dep[j]:
            ready = max(ready, issue_time[i] + 1)
        # structural hazard: earliest free pipeline slot on the unit
        slots = unit_slots[unit]
        s = min(range(len(slots)), key=lambda k: slots[k])
        start = max(ready, slots[s])
        # global issue width: at most target.issue_width issues per cycle
        t = math.floor(start)
        while global_issue.get(t, 0) >= target.issue_width:
            t += 1
        start = max(start, float(t))
        global_issue[math.floor(start)] = global_issue.get(math.floor(start), 0) + 1
        occupancy = inv_tp + (
            ins.meta.get("bytes", 0) / target.bytes_per_cycle_hbm
            if ins.opcode.startswith("dma.")
            else 0.0
        )
        slots[s] = start + occupancy
        issue_time[j] = start
        finish_time[j] = start + eff_lat
        scheduled[j] = True
        if unit == "dma":
            dma_busy += occupancy
        elif unit in ("mxu", "vpu", "fma", "alu", "load", "store"):
            compute_busy += inv_tp

    return max(finish_time), dma_busy, compute_busy


def analyze_ilp(
    visa: VisaProgram, target: HardwareTarget, double_buffer: bool = False
) -> IlpReport:
    spans = identify_loop_spans(visa)
    n = len(visa.instrs)

    # block boundaries: labels and jumps terminate blocks
    boundaries = set()
    for i, ins in enumerate(visa.instrs):
        if ins.opcode in ("label", "scalar.jump"):
            boundaries.add(i)

    mult = [1.0] * n
    for span in spans:
        for i in range(span.start, span.end + 1):
            mult[i] *= span.trips

    blocks: List[Tuple[int, float, float]] = []
    total = 0.0
    dma_total = 0.0
    compute_total = 0.0
    hidden = 0.0
    start = 0
    i = 0
    while i <= n:
        if i == n or i in boundaries:
            seg = visa.instrs[start:i]
            if seg:
                execs = mult[start]
                makespan, dma_busy, comp_busy = schedule_block(
                    seg, target, double_buffer
                )
                if makespan > 0:
                    if double_buffer:
                        # steady state: DMA for step g+1 overlaps compute of g
                        makespan = max(makespan, dma_busy)
                        hidden += min(dma_busy, comp_busy) * execs
                    blocks.append((start, makespan, execs))
                    total += makespan * execs
                    dma_total += dma_busy * execs
                    compute_total += comp_busy * execs
            start = i + 1
        i += 1

    hidden_frac = (hidden / dma_total) if dma_total > 0 else 0.0
    return IlpReport(
        total_cycles=total,
        blocks=tuple(blocks),
        dma_cycles=dma_total,
        compute_cycles=compute_total,
        hidden_dma_frac=hidden_frac,
    )
