"""Joint IR/low-level parsing — paper Algorithm 1 (+ Algorithm 3).

The VISA stream is flat: labels, register init (``scalar.addr`` with an init
value), register update (``scalar.loop``), and conditional jumps. Loop
structure must be *recovered*, exactly as the paper recovers it from x86 asm
or PTX:

1. **IDENTIFY-LOOP-LBB** — a basic block is a loop candidate iff some jump
   instruction ``j`` targets a label positioned *above* ``j`` (backward jump).
2. **Algorithm 3 trip-count recovery** — maintain a register-init map and a
   register-update map while scanning the stream; at an eligible condition
   check (the jump), derive iterations from (init value, update step, end
   bound).
3. **PATTERN-MATCH-LOOP** — walk the TIR's pre-order loop list and the
   recovered loop blocks in tandem, matching on iteration boundary. Loops the
   backend collapsed (vectorized / unrolled / tensorized) have no block and
   are skipped by the forward scan.
4. **COUNT-INSTRUCTION** — every instruction's dynamic count is the product
   of the trip counts of all recovered loop spans containing it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.tir import Loop, Program
from repro.core.visa import VInstr, VisaProgram

SIGNIFICANT = {
    # the paper's vfmadd/vmov (CPU) and fma/ld/st (PTX) analogues
    "mxu.matmul",
    "vpu.fma",
    "vpu.load",
    "vpu.store",
    "simd.fma",
    "simd.load",
    "simd.store",
    "simd.broadcast",
    "dma.load",
    "dma.store",
}


@dataclasses.dataclass
class LoopSpan:
    label: str
    start: int  # index of the label instruction
    end: int  # index of the backward jump
    trips: int


@dataclasses.dataclass
class InstReport:
    counts: Dict[str, float]  # opcode -> dynamic instruction count
    dma_bytes: float  # dynamic HBM<->VMEM DMA payload
    per_loop_simd: Dict[str, float]  # label -> dynamic significant instrs
    matched: List[Tuple[str, str]]  # (tir var, visa label) pairs (Alg. 1 result)
    wasted_lane_frac: float  # tail-lane waste, weighted by dynamic count
    spans: List[LoopSpan]
    multiplicity: List[float]  # per instruction index

    def total_significant(self) -> float:
        return sum(v for k, v in self.counts.items() if k in SIGNIFICANT)


def identify_loop_spans(visa: VisaProgram) -> List[LoopSpan]:
    """Faithful loop identification + Algorithm 3 trip recovery."""
    label_pos: Dict[str, int] = {}
    for idx, ins in enumerate(visa.instrs):
        if ins.opcode == "label":
            label_pos[ins.dest] = idx

    reg_init: Dict[str, int] = {}
    reg_update: Dict[str, int] = {}
    spans: List[LoopSpan] = []
    for idx, ins in enumerate(visa.instrs):
        if ins.opcode == "scalar.addr" and "init" in ins.meta:
            reg_init[ins.dest] = ins.meta["init"]
        elif ins.opcode == "scalar.loop" and "update" in ins.meta:
            reg_update[ins.dest] = ins.meta["update"]
        elif ins.opcode == "scalar.jump":
            tgt = ins.meta.get("target")
            if tgt in label_pos and label_pos[tgt] < idx:  # backward jump
                reg = ins.srcs[0]
                init = reg_init.get(reg, 0)
                step = reg_update.get(reg, 1)
                bound = ins.meta.get("bound", init + step)
                trips = max(1, math.ceil((bound - init) / step))
                spans.append(LoopSpan(tgt, label_pos[tgt], idx, trips))
    return spans


def _pattern_match(for_loop: Loop, span: LoopSpan) -> bool:
    """PATTERN-MATCH-LOOP: same iteration boundary."""
    return for_loop.extent == span.trips


def match_loops(program: Program, visa: VisaProgram) -> Tuple[List[Tuple[Loop, LoopSpan]], List[LoopSpan]]:
    """Algorithm 1 main procedure."""
    for_loops = list(program.walk_loops())  # PREORDER-DFS-FOR-LOOP
    spans = identify_loop_spans(visa)  # IDENTIFY-LOOP-LBB (stream order)
    matched: List[Tuple[Loop, LoopSpan]] = []
    idx = 0
    for span in spans:
        j = idx
        while j < len(for_loops):
            if _pattern_match(for_loops[j], span):
                matched.append((for_loops[j], span))
                idx = j + 1
                break
            j += 1  # collapsed (vector/unroll/tensor) loops have no block
    return matched, spans


def count_instructions(program: Program, visa: VisaProgram) -> InstReport:
    matched, spans = match_loops(program, visa)

    n = len(visa.instrs)
    mult = [1.0] * n
    for span in spans:
        for i in range(span.start, span.end + 1):
            mult[i] *= span.trips

    counts: Dict[str, float] = {}
    dma_bytes = 0.0
    waste_num = 0.0
    waste_den = 0.0
    per_loop: Dict[str, float] = {s.label: 0.0 for s in spans}
    for i, ins in enumerate(visa.instrs):
        if ins.opcode == "label":
            continue
        counts[ins.opcode] = counts.get(ins.opcode, 0.0) + mult[i]
        if ins.opcode.startswith("dma."):
            dma_bytes += ins.meta.get("bytes", 0) * mult[i]
        if "waste" in ins.meta:
            waste_num += ins.meta["waste"] * mult[i]
            waste_den += mult[i]
        if ins.opcode in SIGNIFICANT:
            for span in spans:
                if span.start <= i <= span.end:
                    per_loop[span.label] += mult[i]
    return InstReport(
        counts=counts,
        dma_bytes=dma_bytes,
        per_loop_simd=per_loop,
        matched=[(lp.var, sp.label) for lp, sp in matched],
        wasted_lane_frac=(waste_num / waste_den) if waste_den else 0.0,
        spans=spans,
        multiplicity=mult,
    )
