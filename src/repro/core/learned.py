"""Learned schedule ranker trained offline from the JSONL schedule store.

The static ``cm1`` model ranks without touching hardware; the fleet's store
accumulates exactly the (op signature, config, score) pairs that learned
cost models train on (TLP; the TPU learned performance model). This module
is the numpy-only counterpart: a ridge regression in log space over

  * the ``cm1`` static feature vector (``cost_model.extract_features`` —
    ILP makespan, locality traffic, ``core.instcount`` instruction counts,
    alignment/occupancy/overflow penalties),
  * the schedule's config-dict knobs (log2 block sizes, loop order,
    unroll, double-buffering), and
  * graph-level ``core.hlo_features`` counts when a record's meta carries
    HLO text (``meta["hlo"]``; zeros for TIR-space records).

**Lineages.** Stored scores are only comparable within one
``record_version`` lineage: datasheet ``cm1`` predictions, host-calibrated
``cm1-cal-<fp>`` fits, and measured ``cm1-meas`` samples live on different
scales. Training therefore standardises targets *per lineage* — every
lineage contributes rank information, no lineage's scale leaks into
another's — and the artifact records which lineages (and how many samples)
it saw. Records written by a learned ranker itself (version containing
``+lr``) are excluded: a model must never train on its own write-backs.

**Serving.** ``core.tuner.rank_space``/``best_schedule`` serve the model as
a hybrid: static ``cm1`` scores and prunes the space, the model re-ranks
the top-K candidates (``LearnedRanker.rerank``) — zero hardware
measurements at ranking time. Hybrid write-backs carry the version
``<base>+lr<fp>`` so they never collide with pure static records.

**Artifact.** ``save_ranker``/``load_ranker`` persist the model as JSON
(schema ``tuna-learned-v1``): the payload is sha1-digested
(content-addressed, torn copies fail loudly), the parameters are
fingerprinted (``fingerprint`` = sha1 over the canonical parameter set, the
``<fp>`` in the version tag) and re-verified at load, and a model built
under a different ``COST_MODEL_VERSION`` raises ``StaleSnapshotError``
exactly like stale snapshots/bundles — never silently served.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import tempfile
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import cost_model, op_registry
from repro.core.cost_model import COST_MODEL_VERSION
from repro.core.op_registry import Space
from repro.hw.target import HardwareTarget

LEARNED_SCHEMA = "tuna-learned-v1"
LEARNED_POINTER_SCHEMA = "tuna-learned-pointer-v1"

# Static cm1 features folded into the learned vector (log1p-compressed:
# they span ~9 orders of magnitude across shapes).
_STATIC_LOG = ("ilp_cycles", "movement_bytes", "unhidden_dma_cycles",
               "arith_ops", "ldst_ops", "dispatch_calls", "parallel_extent",
               "vmem_overflow")
_STATIC_RAW = ("alignment_waste", "occupancy_penalty")
# Config-dict knob features (0 when a space has no such knob): the union of
# every registered OpDef's declared KnobFeatures, group-major (log2 tile
# sizes | raw counts | flags | choice one-hots). Legacy families register
# first, so the historical column layout is a stable prefix and new op
# families extend each group; models saved under an older layout are
# re-aligned by name at predict time.
KNOB_FEATURES = op_registry.knob_feature_union()
# Graph-level hlo_features counts (records carrying meta["hlo"]).
_HLO_COUNTS = ("n_fusions", "n_dots", "n_layout_ops", "n_while")

FEATURE_NAMES: Tuple[str, ...] = (
    tuple(f"log_{n}" for n in _STATIC_LOG)
    + _STATIC_RAW
    + tuple(n for kf in KNOB_FEATURES for n in kf.feature_names())
    + tuple(f"hlo_{n}" for n in _HLO_COUNTS)
)


def featurize(space: Space, target: HardwareTarget, cfg: Dict,
              hlo_text: Optional[str] = None) -> np.ndarray:
    """Feature vector for one (space, config) candidate — purely static:
    TIR instantiation + VISA lowering (``core.instcount`` runs inside
    ``extract_features``), the config dict itself, and optional HLO-text
    counts. Never touches hardware."""
    prog, meta = space.instantiate(cfg)
    f = cost_model.extract_features(prog, target, meta).as_dict()
    row: List[float] = [math.log1p(max(0.0, float(f[n])))
                        for n in _STATIC_LOG]
    row += [float(f[n]) for n in _STATIC_RAW]
    for kf in KNOB_FEATURES:
        v = cfg.get(kf.name)
        if kf.kind == "log2":
            row.append(math.log2(v) if isinstance(v, (int, float)) and v > 0
                       else 0.0)
        elif kf.kind == "raw":
            row.append(float(v or 0))
        elif kf.kind == "flag":
            row.append(1.0 if v else 0.0)
        else:  # choice one-hot
            row += [1.0 if v == c else 0.0 for c in kf.choices]
    row += list(hlo_counts(hlo_text))
    return np.asarray(row, dtype=np.float64)


def hlo_counts(hlo_text: Optional[str]) -> Tuple[float, ...]:
    """Graph-level sub-vector from ``core.hlo_features.parse_hlo`` —
    zeros when no HLO text is attached (TIR-space records)."""
    if not hlo_text:
        return (0.0,) * len(_HLO_COUNTS)
    from repro.core.hlo_features import parse_hlo

    hf = parse_hlo(hlo_text)
    return tuple(float(getattr(hf, n)) for n in _HLO_COUNTS)


# -- op-signature round trip -------------------------------------------------


def space_from_signature(sig: str,
                         target: HardwareTarget) -> Optional[Space]:
    """Reconstruct the schedule space a record's op signature came from
    (inverse of ``Space.signature``), via the operator registry. None for
    op families the registry does not know (e.g. graph-level ``cell[...]``
    records) — those rows are skipped by the trainer, they don't fail it."""
    return op_registry.space_from_signature(sig, target.kind)


def lineage_of(version: str) -> str:
    """The score lineage a record's version tag names. Distinct lineages
    (datasheet, per-host calibrated fits, measured samples) carry
    incomparable score scales and are standardised separately."""
    return version


def measured_version() -> str:
    """Version tag for measured per-config sample records (what
    ``benchmarks/topk_ratio.py --collect`` appends): its own lineage, so
    measured seconds never compare against static scores, and the ``-meas``
    suffix keeps them from ever warm-hitting as search-grade records."""
    return f"{COST_MODEL_VERSION}-meas"


# -- the model ---------------------------------------------------------------

@dataclasses.dataclass
class LearnedRanker:
    """Ridge regression over ``FEATURE_NAMES`` predicting standardised
    log score — rank information only (scale-free by construction)."""

    weights: np.ndarray
    bias: float
    mean: np.ndarray
    std: np.ndarray
    feature_names: Tuple[str, ...] = FEATURE_NAMES
    cost_model_version: str = COST_MODEL_VERSION
    lineages: Dict[str, int] = dataclasses.field(default_factory=dict)
    l2: float = 1e-2
    built_at: Optional[float] = None

    def params(self) -> Dict:
        """Canonical parameter set — exactly what the fingerprint covers."""
        return {
            "weights": [float(w) for w in np.asarray(self.weights).ravel()],
            "bias": float(self.bias),
            "mean": [float(v) for v in np.asarray(self.mean).ravel()],
            "std": [float(v) for v in np.asarray(self.std).ravel()],
            "feature_names": list(self.feature_names),
            "cost_model_version": self.cost_model_version,
            "lineages": {k: int(v) for k, v in sorted(self.lineages.items())},
            "l2": float(self.l2),
        }

    def fingerprint(self) -> str:
        blob = json.dumps(self.params(), sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()

    def hybrid_version(self, base: Optional[str] = None) -> str:
        """Record-version tag for hybrid (cm1-prune + learned-rerank)
        results: ``<base>+lr<fp8>`` — its own lineage, mirroring
        ``record_version``'s calibrated fingerprinting."""
        return f"{base or self.cost_model_version}+lr{self.fingerprint()[:8]}"

    @property
    def version(self) -> str:
        return self.hybrid_version(self.cost_model_version)

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = self._align(np.atleast_2d(np.asarray(X, dtype=np.float64)))
        Z = (X - self.mean) / self.std
        return Z @ self.weights + self.bias

    def _align(self, X: np.ndarray) -> np.ndarray:
        """Project rows laid out as today's ``FEATURE_NAMES`` onto the
        layout this model was trained with. Registering a new op family
        inserts knob columns; a model from before the registration keeps
        working — its known columns are matched by name, its unknown ones
        (none, for an insert-only change) read as zero."""
        if self.feature_names == FEATURE_NAMES:
            return X
        if X.shape[1] != len(FEATURE_NAMES):
            return X  # caller already built rows in the model's own layout
        idx = {n: i for i, n in enumerate(FEATURE_NAMES)}
        out = np.zeros((X.shape[0], len(self.feature_names)))
        for j, name in enumerate(self.feature_names):
            i = idx.get(name)
            if i is not None:
                out[:, j] = X[:, i]
        return out

    def score_config(self, space: Space, target: HardwareTarget,
                     cfg: Dict) -> float:
        return float(self.predict(featurize(space, target, cfg))[0])

    def rerank(self, space: Space, target: HardwareTarget,
               ranked: Sequence[Tuple[Dict, float]],
               top: int = 32) -> List[Tuple[Dict, float]]:
        """Hybrid step: re-order the first ``top`` statically-ranked
        (config, static_score) candidates by learned prediction; the
        pruned tail keeps its static order. Scores in the returned pairs
        stay the static ones (the stored lineage is explicit about what a
        score means)."""
        ranked = list(ranked)
        k = max(0, min(int(top), len(ranked)))
        if k < 2:
            return ranked
        head = ranked[:k]
        X = np.stack([featurize(space, target, cfg) for cfg, _ in head])
        preds = self.predict(X)
        idx = sorted(range(k), key=lambda i: (preds[i], head[i][1]))
        return [head[i] for i in idx] + ranked[k:]


def fit_ranker(X: np.ndarray, y: np.ndarray,
               lineage_ids: Sequence[str],
               l2: float = 1e-2) -> LearnedRanker:
    """Ridge fit on standardised features vs per-lineage-standardised log
    targets. Lineages with a single sample contribute nothing after
    centring (their target becomes 0) but cost nothing either."""
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    if X.ndim != 2 or len(X) != len(y) or len(y) == 0:
        raise ValueError(f"bad training set: X{X.shape} y{y.shape}")
    logy = np.log(np.maximum(y, 1e-30))
    t = np.zeros_like(logy)
    counts: Dict[str, int] = {}
    for lin in sorted(set(lineage_ids)):
        m = np.asarray([li == lin for li in lineage_ids])
        counts[lin] = int(m.sum())
        mu = logy[m].mean()
        sd = logy[m].std()
        t[m] = (logy[m] - mu) / (sd if sd > 1e-12 else 1.0)
    mean = X.mean(axis=0)
    std = X.std(axis=0)
    std[std < 1e-12] = 1.0
    Z = (X - mean) / std
    A = Z.T @ Z + l2 * len(y) * np.eye(Z.shape[1])
    w = np.linalg.solve(A, Z.T @ t)
    return LearnedRanker(weights=w, bias=float(t.mean() - (Z @ w).mean()),
                         mean=mean, std=std, lineages=counts, l2=float(l2))


# -- artifact persistence ----------------------------------------------------

def _params_sha1(params: Dict) -> str:
    return hashlib.sha1(
        json.dumps(params, sort_keys=True, default=float).encode()
    ).hexdigest()


def save_ranker(model: LearnedRanker, path: str) -> str:
    """Write the model artifact (atomic temp-file + replace). Header
    fields (schema, version, fingerprint, sha1) come before the parameter
    payload; ``built_at`` sits outside the digests so re-saving identical
    parameters keeps the same content address. Returns the payload sha1."""
    params = model.params()
    fp = model.fingerprint()
    sha1 = _params_sha1(params)
    model.built_at = round(time.time(), 3)
    obj = {
        "schema": LEARNED_SCHEMA,
        "cost_model_version": model.cost_model_version,
        "version": model.version,
        "fingerprint": fp,
        "sha1": sha1,
        "built_at": model.built_at,
        "model": params,
    }
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".learned.tmp")
    try:
        with os.fdopen(fd, "w", encoding="utf-8") as f:
            json.dump(obj, f)
            f.write("\n")
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return sha1


def load_ranker(path: str) -> LearnedRanker:
    """Load + verify a model artifact; follows a ``latest`` pointer.

    Raises ``ValueError`` on schema mismatch, payload-digest corruption
    (torn transport copies), or a parameter-fingerprint mismatch (the
    ``+lr<fp>`` in the version tag no longer names these weights), and
    ``repro.tuna.cache.StaleSnapshotError`` when the model was trained
    under a different ``COST_MODEL_VERSION`` — its features and training
    scores would silently mean something else."""
    path = os.fspath(path)
    with open(path, "r", encoding="utf-8") as f:
        obj = json.load(f)
    if isinstance(obj, dict) and obj.get("schema") == LEARNED_POINTER_SCHEMA:
        target = os.path.join(os.path.dirname(os.path.abspath(path)),
                              obj["artifact"])
        return load_ranker(target)
    if not isinstance(obj, dict) or obj.get("schema") != LEARNED_SCHEMA:
        schema = obj.get("schema") if isinstance(obj, dict) else None
        raise ValueError(f"{path}: not a learned-ranker artifact "
                         f"(schema={schema!r}, want {LEARNED_SCHEMA!r})")
    params = obj.get("model") or {}
    if _params_sha1(params) != obj.get("sha1"):
        raise ValueError(f"{path}: learned-model digest mismatch (corrupt "
                         f"or torn copy); retrain with "
                         f"`python -m repro.tuna train`")
    model = LearnedRanker(
        weights=np.asarray(params["weights"], dtype=np.float64),
        bias=float(params["bias"]),
        mean=np.asarray(params["mean"], dtype=np.float64),
        std=np.asarray(params["std"], dtype=np.float64),
        feature_names=tuple(params["feature_names"]),
        cost_model_version=str(params["cost_model_version"]),
        lineages=dict(params.get("lineages", {})),
        l2=float(params.get("l2", 1e-2)),
        built_at=obj.get("built_at"),
    )
    if model.fingerprint() != obj.get("fingerprint"):
        raise ValueError(
            f"{path}: learned-model fingerprint mismatch — the stored "
            f"version tag {obj.get('version')!r} does not name these "
            f"parameters (tampered or mis-assembled artifact); retrain "
            f"with `python -m repro.tuna train`")
    if model.cost_model_version != COST_MODEL_VERSION:
        from repro.tuna.cache import StaleSnapshotError

        raise StaleSnapshotError(
            f"{path}: learned model was trained under cost-model version "
            f"{model.cost_model_version!r} but this process runs "
            f"{COST_MODEL_VERSION!r}; its features and training scores no "
            f"longer mean the same thing. Retrain it: "
            f"`python -m repro.tuna train`")
    return model


def spearman(a: Iterable[float], b: Iterable[float]) -> float:
    """Spearman rank correlation (numpy-only) — the eval metric: a ranker
    is judged on ordering, not on absolute score scale."""
    a = np.asarray(list(a), dtype=np.float64)
    b = np.asarray(list(b), dtype=np.float64)
    if len(a) < 2:
        return 0.0
    ra = np.argsort(np.argsort(a)).astype(np.float64)
    rb = np.argsort(np.argsort(b)).astype(np.float64)
    sa, sb = ra.std(), rb.std()
    if sa < 1e-12 or sb < 1e-12:
        return 0.0
    return float(((ra - ra.mean()) * (rb - rb.mean())).mean() / (sa * sb))
