"""Analytical data-locality model — paper §III-A.2, Algorithm 2.

Bottom-up traversal of the loop/access tree computing, per tensor:

* **data footprint** — distinct elements touched in the subtree (exact affine
  box arithmetic instead of the paper's ISL; our transformation spaces only
  produce regular tilings for which this is exact — property-tested);
* **data movement** — elements that must cross the fast-memory boundary
  (L1 for CPU, VMEM for TPU), using the paper's rules:

  - leaf access: Dmov = Dfp = 1;
  - loop node whose single-iteration footprint fits in cache: Dmov = Dfp;
  - otherwise: Dmov = trip_count × Dmov(single iteration), except tensors
    whose reuse status survives (invariant to this loop var, own footprint
    fits, and the *interference* — the other tensors' per-iteration
    footprint — does not exceed cache: the paper's "continuous loop nodes
    that do not access this tensor" condition).

The returned movement (bytes) is the model's estimate of main-memory (HBM /
DRAM) traffic for one execution of the program.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Mapping, Tuple

from repro.core.tir import (
    Access,
    Compute,
    Loop,
    Program,
    access_footprint,
)


@dataclasses.dataclass
class _TensorState:
    # canonical pattern key -> representative access
    patterns: Dict[Tuple, Access]
    mov: float  # elements moved within the subtree (one execution of it)
    reuse: bool

    def vars(self) -> FrozenSet[str]:
        out: FrozenSet[str] = frozenset()
        for acc in self.patterns.values():
            out |= acc.vars
        return out


@dataclasses.dataclass(frozen=True)
class LocalityReport:
    movement_bytes: float
    footprint_bytes: float
    per_tensor_movement: Mapping[str, float]  # bytes
    per_tensor_footprint: Mapping[str, float]  # bytes


def _footprint(state: _TensorState, extents, live) -> float:
    """Union footprint (elements) of a tensor's access patterns with
    ``live`` vars ranging. Identical canonical patterns are deduplicated;
    distinct patterns are summed (upper bound, exact for disjoint regions)."""
    total = 0.0
    seen = set()
    for key, acc in state.patterns.items():
        # re-canonicalise under the live set: two name-distinct patterns can
        # coincide once dead vars are fixed
        live_key = (
            acc.tensor,
            tuple(
                (
                    tuple(sorted((c, extents[v]) for v, c in ix.terms if v in live)),
                    ix.const,
                )
                for ix in acc.indices
            ),
        )
        if live_key in seen:
            continue
        seen.add(live_key)
        total += access_footprint(acc, extents, live)
    return total


def analyze_locality(program: Program, cache_bytes: int) -> LocalityReport:
    extents = program.extents()
    dtype_bytes = {t.name: t.dtype_bytes for t in program.tensors}

    def visit(node) -> Tuple[Dict[str, _TensorState], FrozenSet[str]]:
        """Returns (per-tensor state, vars live in this subtree)."""
        if isinstance(node, Compute):
            states: Dict[str, _TensorState] = {}
            for acc in node.accesses:
                key = acc.canonical(extents)
                st = states.get(acc.tensor)
                if st is None:
                    st = _TensorState(patterns={}, mov=0.0, reuse=True)
                    states[acc.tensor] = st
                if key not in st.patterns:
                    st.patterns[key] = acc
                    st.mov += 1.0  # leaf: Dmov = Dfp = 1
            return states, frozenset()

        assert isinstance(node, Loop)
        # ---- merge sequential children --------------------------------
        merged: Dict[str, _TensorState] = {}
        sub_vars: FrozenSet[str] = frozenset()
        child_movs: Dict[str, float] = {}
        for child in node.body:
            cstates, cvars = visit(child)
            sub_vars |= cvars
            if isinstance(child, Loop):
                sub_vars |= frozenset([child.var])
            for name, cst in cstates.items():
                st = merged.get(name)
                if st is None:
                    merged[name] = _TensorState(
                        patterns=dict(cst.patterns), mov=0.0, reuse=cst.reuse
                    )
                else:
                    st.patterns.update(cst.patterns)
                    st.reuse = st.reuse and cst.reuse
                child_movs[name] = child_movs.get(name, 0.0) + cst.mov

        live_iter = sub_vars  # this loop's var fixed; inner vars range
        live_full = sub_vars | frozenset([node.var])

        fp_iter = {
            name: _footprint(st, extents, live_iter) for name, st in merged.items()
        }
        fp_iter_all_bytes = sum(
            fp_iter[name] * dtype_bytes[name] for name in merged
        )

        for name, st in merged.items():
            fp_full = _footprint(st, extents, live_full)
            fp_full_bytes = fp_full * dtype_bytes[name]
            if fp_iter_all_bytes <= cache_bytes:
                # single-iteration working set resident => each element of the
                # full-loop footprint crosses the boundary exactly once
                st.mov = fp_full
                # reuse survives (deeper thrash impossible: monotone footprints)
            else:
                invariant = node.var not in st.vars()
                interference_bytes = (
                    fp_iter_all_bytes - fp_iter[name] * dtype_bytes[name]
                )
                if (
                    invariant
                    and st.reuse
                    and fp_full_bytes <= cache_bytes
                    and interference_bytes <= cache_bytes
                ):
                    st.mov = fp_full  # stays resident across iterations
                else:
                    # evicted between iterations: pay per-iteration movement
                    # (the merged children's movement) every trip
                    mov_iter = child_movs.get(name, fp_iter[name])
                    st.mov = node.extent * mov_iter
                    st.reuse = False
        return merged, live_full

    # virtual root over all top-level loops
    root = Loop(var="__root__", extent=1, body=tuple(program.roots), kind="serial")
    states, live = visit(root)
    live = live - frozenset(["__root__"])

    per_mov = {
        name: st.mov * dtype_bytes[name] for name, st in states.items()
    }
    per_fp = {
        name: _footprint(st, extents, live) * dtype_bytes[name]
        for name, st in states.items()
    }
    return LocalityReport(
        movement_bytes=sum(per_mov.values()),
        footprint_bytes=sum(per_fp.values()),
        per_tensor_movement=per_mov,
        per_tensor_footprint=per_fp,
    )
