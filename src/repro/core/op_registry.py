"""Declarative operator registry: one ``OpDef`` per tunable operator family.

Instead of hand-coding a ``Space`` subclass per kernel, an operator family is
described once by an :class:`OpDef` — its scalar attributes (shapes, dtype
width, flags), a knob generator (tile sizes / loop order / unroll /
double-buffer choices per target kind), a TIR builder template, optional
kernel-bundle reconstruction, learned-ranker knob features, and named tuning
presets.  Everything downstream is derived from the registry:

  * ``configs/tuna_ops.py``  enumerates ``OPERATORS`` from registered presets.
  * ``core/learned``         builds its knob feature columns from the union of
                             every registered op's :class:`KnobFeature` specs.
  * ``tuna/golden``          reconstructs shapes/dtypes for kernel bundles via
                             :func:`parse_signature` + ``OpDef.bundle_fn``
                             instead of regex-parsing ``matmul[...]`` strings.
  * ``kernels/ops``          resolves block-spec picker signatures here.

The canonical signature grammar is ``family[k1=v1,k2=v2,...]`` with keys
sorted lexicographically; values may be int, bool (``True``/``False``) or a
restricted string token.  Signatures for the four legacy ops are byte-
identical to the pre-registry format, so every existing schedule-DB record,
snapshot and golden release loads unchanged.
"""
from __future__ import annotations

import dataclasses
import itertools
import re
import sys
from typing import (Any, Callable, Dict, Iterator, List, Mapping, Optional,
                    Tuple)

import numpy as np

# dtype widths the kernel bundler understands (bytes -> jax dtype name)
DTYPE_BY_BYTES: Dict[int, str] = {2: "bfloat16", 4: "float32"}

_SIG_RE = re.compile(r"([A-Za-z0-9_]+)\[([^\]]*)\]$")
_SIG_STR_VALUE_RE = re.compile(r"[A-Za-z0-9_.+-]+")

# attribute keys that are schedule state, never operator identity
_SIG_EXCLUDE = ("knobs", "target_kind", "name")


def _format_sig_value(key: str, value: Any) -> str:
    """Render one signature attribute deterministically.

    bools render as ``True``/``False`` (checked before int: bool is an int
    subclass), ints as decimal, strings must be plain tokens so the grammar
    stays unambiguous (no ``,``/``=``/``]``)."""
    if isinstance(value, bool):
        return "True" if value else "False"
    if isinstance(value, int):
        return str(value)
    if isinstance(value, str):
        if not _SIG_STR_VALUE_RE.fullmatch(value):
            raise ValueError(
                f"signature attr {key}={value!r} is not a plain token")
        return value
    raise TypeError(f"unsupported signature attr type for {key}: {value!r}")


def _parse_sig_value(text: str) -> Any:
    if text == "True":
        return True
    if text == "False":
        return False
    try:
        return int(text)
    except ValueError:
        return text


def parse_signature(sig: str) -> Tuple[str, Dict[str, Any]]:
    """``"matmul[K=64,M=128,N=128,dtype_bytes=4]"`` -> ("matmul", attrs).

    Raises ``ValueError`` on anything that does not match the grammar."""
    m = _SIG_RE.fullmatch(sig.strip())
    if not m:
        raise ValueError(f"unparseable op signature: {sig!r}")
    name, inner = m.group(1), m.group(2)
    attrs: Dict[str, Any] = {}
    for field in filter(None, inner.split(",")):
        if "=" not in field:
            raise ValueError(f"bad signature field {field!r} in {sig!r}")
        k, v = field.split("=", 1)
        attrs[k] = _parse_sig_value(v)
    return name, attrs


class Space:
    """Base schedule space: a dict of named discrete knobs.

    ES operates on a continuous θ that ``decode`` buckets into knob choices;
    ``enumerate`` walks the cartesian product for exhaustive/top-k tuning."""

    name: str = "space"

    def __init__(self) -> None:
        self.knobs: Dict[str, List] = {}

    @property
    def dim(self) -> int:
        return len(self.knobs)

    def decode(self, theta: np.ndarray) -> Dict:
        cfg = {}
        for (name, choices), t in zip(self.knobs.items(), theta):
            # map R -> index via round+clip; theta 0 = centre of the list
            idx = int(round(float(t) + (len(choices) - 1) / 2.0))
            cfg[name] = choices[max(0, min(len(choices) - 1, idx))]
        return cfg

    def default_config(self) -> Dict:
        return {k: v[len(v) // 2] for k, v in self.knobs.items()}

    def enumerate(self, limit: Optional[int] = 10_000) -> Iterator[Dict]:
        """Yield knob configs; ``limit=None`` walks the full product.

        A truncated walk is reported loudly on stderr (and via
        ``enumeration_truncated``) instead of silently dropping the tail —
        ranking a 10k prefix of a 1M-config space is a very different
        experiment from ranking the space."""
        names = list(self.knobs)
        total = self.size()
        truncated = limit is not None and total > limit
        self._enumeration_truncated = truncated
        if truncated:
            print(
                f"[spaces] {self.signature()}: enumeration truncated to "
                f"{limit} of {total} configs; pass limit=None or "
                f"limit>=size() to cover the full space",
                file=sys.stderr,
            )
        for i, combo in enumerate(itertools.product(*self.knobs.values())):
            if truncated and i >= limit:
                return
            yield dict(zip(names, combo))

    @property
    def enumeration_truncated(self) -> bool:
        """True iff the most recent ``enumerate`` call dropped configs."""
        return getattr(self, "_enumeration_truncated", False)

    def size(self) -> int:
        n = 1
        for v in self.knobs.values():
            n *= len(v)
        return n

    def instantiate(self, cfg: Dict) -> Tuple[Any, Any]:
        raise NotImplementedError

    def signature(self) -> str:
        """Canonical operator signature, e.g. ``matmul[K=256,M=256,N=256,
        dtype_bytes=4]`` — the ``op`` key of `repro.tuna` schedule records.

        Built from the scalar attributes that define the operator *instance*
        (shapes, dtype width, bool/str flags such as ``causal``), not the
        schedule knobs and not ``target_kind`` (the record's ``target`` field
        already pins the hardware)."""
        attrs = {
            k: v for k, v in vars(self).items()
            if not k.startswith("_") and k not in _SIG_EXCLUDE
            and isinstance(v, (int, str))
        }
        inner = ",".join(
            f"{k}={_format_sig_value(k, attrs[k])}" for k in sorted(attrs))
        return f"{self.name}[{inner}]"


# ---------------------------------------------------------------------------
# OpDef schema
# ---------------------------------------------------------------------------

_REQUIRED = object()


@dataclasses.dataclass(frozen=True)
class AttrSpec:
    """One scalar operator attribute (an axis extent, dtype width, or flag)."""

    name: str
    type: type = int
    default: Any = _REQUIRED

    @property
    def required(self) -> bool:
        return self.default is _REQUIRED

    def coerce(self, value: Any) -> Any:
        if self.type is bool:
            if not isinstance(value, bool):
                raise ValueError(f"attr {self.name} expects bool, got {value!r}")
            return value
        if self.type is int:
            if isinstance(value, bool) or not isinstance(value, int):
                raise ValueError(f"attr {self.name} expects int, got {value!r}")
            return value
        if self.type is str:
            if not isinstance(value, str):
                raise ValueError(f"attr {self.name} expects str, got {value!r}")
            return value
        raise TypeError(f"unsupported attr type {self.type!r}")


@dataclasses.dataclass(frozen=True)
class KnobFeature:
    """How one schedule knob enters the learned ranker's feature vector.

    kind: "log2" (log2 of a tile size), "raw" (small count, e.g. unroll),
    "flag" (bool 0/1), "choice" (one-hot over ``choices``)."""

    name: str
    kind: str
    choices: Tuple[str, ...] = ()

    def feature_names(self) -> Tuple[str, ...]:
        if self.kind == "log2":
            return (f"log2_{self.name}",)
        if self.kind == "choice":
            return tuple(f"{self.name}_{c}" for c in self.choices)
        return (self.name,)


@dataclasses.dataclass(frozen=True)
class Preset:
    """A named operator instance used by ``configs/tuna_ops.OPERATORS``."""

    attrs: Mapping[str, Any]
    kind: str = "cpu"  # default target kind for the preset factory


class BundleSkip(Exception):
    """Raised by an OpDef bundle hook for records it cannot bundle."""

    def __init__(self, reason: str) -> None:
        super().__init__(reason)
        self.reason = reason


@dataclasses.dataclass(frozen=True)
class BundleSpec:
    """Kernel-bundle reconstruction for one schedule record: which Pallas
    kernel family to compile, its input avals ``((shape, dtype_name), ...)``
    and non-knob call params (e.g. ``causal``/``scale``)."""

    kernel: str
    in_avals: Tuple[Tuple[Tuple[int, ...], str], ...]
    params: Mapping[str, Any]


@dataclasses.dataclass
class OpDef:
    """Declarative description of one tunable operator family.

    ``knob_fn(attrs, target_kind)`` returns the knob dict; ``build_fn(attrs,
    cfg, target_kind)`` returns ``(Program, ScheduleMeta)``.  ``bundle_fn``
    (optional) maps ``(attrs, config)`` to a :class:`BundleSpec` or raises
    :class:`BundleSkip`; families without one are skipped at bundling time
    with a counted warning.  ``space_cls`` lets legacy families keep their
    historical constructor classes."""

    name: str
    attrs: Tuple[AttrSpec, ...]
    knob_fn: Callable[[Dict[str, Any], str], Dict[str, List]]
    build_fn: Callable[[Dict[str, Any], Dict, str], Tuple[Any, Any]]
    bundle_fn: Optional[Callable[[Dict[str, Any], Dict], BundleSpec]] = None
    knob_features: Tuple[KnobFeature, ...] = ()
    presets: Mapping[str, Preset] = dataclasses.field(default_factory=dict)
    space_cls: Optional[type] = None
    doc: str = ""

    def coerce_attrs(self, given: Mapping[str, Any]) -> Dict[str, Any]:
        known = {a.name for a in self.attrs}
        unknown = set(given) - known
        if unknown:
            raise ValueError(
                f"{self.name}: unknown attrs {sorted(unknown)}")
        out: Dict[str, Any] = {}
        for spec in self.attrs:
            if spec.name in given:
                out[spec.name] = spec.coerce(given[spec.name])
            elif spec.required:
                raise ValueError(f"{self.name}: missing attr {spec.name}")
            else:
                out[spec.name] = spec.default
        return out


class RegistrySpace(Space):
    """A ``Space`` materialised from an :class:`OpDef` + attribute values."""

    def __init__(self, opdef: OpDef, attrs: Mapping[str, Any],
                 target_kind: str = "tpu") -> None:
        super().__init__()
        self._opdef = opdef
        self.name = opdef.name
        for k, v in opdef.coerce_attrs(attrs).items():
            setattr(self, k, v)
        self.target_kind = target_kind
        self.knobs = opdef.knob_fn(self.attr_values(), target_kind)

    @property
    def opdef(self) -> OpDef:
        return self._opdef

    def attr_values(self) -> Dict[str, Any]:
        return {a.name: getattr(self, a.name) for a in self._opdef.attrs}

    def instantiate(self, cfg: Dict):
        return self._opdef.build_fn(self.attr_values(), cfg, self.target_kind)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, OpDef] = {}
_DEFINITIONS_LOADED = False


def register(opdef: OpDef) -> OpDef:
    """Register (or re-register, e.g. on module reload) an operator family."""
    _REGISTRY[opdef.name] = opdef
    return opdef


def _ensure_definitions() -> None:
    """Import the modules that register op families, exactly once.

    ``core.spaces`` registers the four legacy families first (their knob
    features pin the historical learned-ranker column prefix), then
    ``core.zoo`` adds the model-zoo families."""
    global _DEFINITIONS_LOADED
    if _DEFINITIONS_LOADED:
        return
    _DEFINITIONS_LOADED = True
    import repro.core.spaces  # noqa: F401  (registers legacy ops)
    import repro.core.zoo  # noqa: F401  (registers model-zoo ops)


def families() -> Tuple[str, ...]:
    _ensure_definitions()
    return tuple(_REGISTRY)


def get(name: str) -> OpDef:
    _ensure_definitions()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown operator family {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def lookup(name: str) -> Optional[OpDef]:
    _ensure_definitions()
    return _REGISTRY.get(name)


def make_space(name: str, attrs: Mapping[str, Any],
               target_kind: str = "tpu") -> Space:
    """Build a schedule space for family ``name`` with the given attrs."""
    opdef = get(name)
    coerced = opdef.coerce_attrs(attrs)
    if opdef.space_cls is not None:
        return opdef.space_cls(**coerced, target_kind=target_kind)
    return RegistrySpace(opdef, coerced, target_kind)


def space_from_signature(sig: str, target_kind: str) -> Optional[Space]:
    """Reconstruct the schedule space a record's ``op`` signature came from.

    Returns ``None`` for unknown families or malformed signatures (callers
    skip those lineages)."""
    try:
        name, attrs = parse_signature(sig)
    except ValueError:
        return None
    opdef = lookup(name)
    if opdef is None:
        return None
    try:
        return make_space(name, attrs, target_kind)
    except (TypeError, ValueError):
        return None


def knob_feature_union() -> Tuple[KnobFeature, ...]:
    """Union of every registered op's knob features, group-major
    (log2 | raw | flag | choice), first-registration order within a group.

    Legacy families register first, so the historical learned-ranker feature
    layout is reproduced as a prefix and zoo knobs extend each group."""
    _ensure_definitions()
    groups: Dict[str, List[KnobFeature]] = {
        "log2": [], "raw": [], "flag": [], "choice": []}
    seen: Dict[str, KnobFeature] = {}
    for opdef in _REGISTRY.values():
        for kf in opdef.knob_features:
            if kf.kind not in groups:
                raise ValueError(f"{opdef.name}: bad knob feature kind "
                                 f"{kf.kind!r} for {kf.name!r}")
            prev = seen.get(kf.name)
            if prev is None:
                seen[kf.name] = kf
                groups[kf.kind].append(kf)
            elif prev.kind != kf.kind:
                raise ValueError(
                    f"knob {kf.name!r} registered as both {prev.kind!r} "
                    f"and {kf.kind!r}")
            elif kf.kind == "choice" and kf.choices != prev.choices:
                merged = prev.choices + tuple(
                    c for c in kf.choices if c not in prev.choices)
                merged_kf = dataclasses.replace(prev, choices=merged)
                groups["choice"][groups["choice"].index(prev)] = merged_kf
                seen[kf.name] = merged_kf
    return tuple(groups["log2"] + groups["raw"]
                 + groups["flag"] + groups["choice"])


def all_presets() -> Dict[str, Tuple[str, Preset]]:
    """``{preset_name: (family, Preset)}`` across the registry, in
    registration order (family) then declaration order (preset)."""
    _ensure_definitions()
    out: Dict[str, Tuple[str, Preset]] = {}
    for opdef in _REGISTRY.values():
        for pname, preset in opdef.presets.items():
            if pname in out:
                raise ValueError(f"duplicate preset name {pname!r} "
                                 f"({out[pname][0]} vs {opdef.name})")
            out[pname] = (opdef.name, preset)
    return out


def bundle_for(sig: str, config: Mapping[str, Any]) -> BundleSpec:
    """Resolve a schedule record to a kernel-bundle spec via its family's
    bundle hook.  Raises :class:`BundleSkip` with a human-readable reason for
    anything unbundleable (unknown family, missing hook, wrong knobs/dtype)."""
    try:
        name, attrs = parse_signature(sig)
    except ValueError as e:
        raise BundleSkip(str(e)) from None
    opdef = lookup(name)
    if opdef is None:
        raise BundleSkip("no Pallas kernel for this op family")
    if opdef.bundle_fn is None:
        raise BundleSkip("no Pallas kernel for this op family")
    try:
        coerced = opdef.coerce_attrs(attrs)
    except ValueError as e:
        raise BundleSkip(str(e)) from None
    return opdef.bundle_fn(coerced, dict(config))
