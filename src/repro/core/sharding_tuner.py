"""Distributed-level Tuna: static selection of the *distribution* schedule.

The paper's Eq. 1 with a different (e, T_e): the program is a whole
(arch × shape) training/serving step, the transformation space is the
distribution knob grid (grad accumulation depth, sequence parallelism,
gradient compression, optimizer-state dtype), the "low-level code" is the
compiled HLO of the dry-run, and the cost model is the three-term roofline

    c(t) = max(compute_s, memory_s, collective_s) + λ·max(0, HBM overflow)

— every term derived statically from the compiled artifact (loop-scaled
collective bytes) + datasheet constants, never from execution. The space is
small (≤ 24 points) so the search is exhaustive; ES (core/es.py) is used for
the larger kernel spaces.

This is what §Perf's hillclimbs run under the hood; it is also exposed as
``tune_distribution`` for end users.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Tuple

HBM_BYTES = 16 * 1024**3
OVERFLOW_LAMBDA = 1e-9  # seconds per byte over HBM — dominates when violated


@dataclasses.dataclass
class DistResult:
    variant: Dict[str, Any]
    terms: Dict[str, float]
    cost: float
    record: Dict[str, Any]


def default_space(kind: str, base_accum: int) -> List[Dict[str, Any]]:
    if kind != "train":
        return [dict(sp_seq=v) for v in (False, True)]
    accums = sorted({max(1, base_accum // 4), max(1, base_accum // 2),
                     base_accum, base_accum * 2})
    grid = itertools.product(accums, (None, "int8"), (True, False))
    return [dict(accum_steps=a, grad_compression=g, sp_seq=s)
            for a, g, s in grid]


def evaluate_variant(arch: str, shape: str, variant: Dict[str, Any],
                     run_cell_fn, structural_terms_fn) -> DistResult:
    record = run_cell_fn(arch, shape, variant=variant, verbose=False)
    terms = structural_terms_fn(arch, shape, record)
    peak = record["mem"]["temp_bytes"] + record["mem"]["argument_bytes"]
    overflow = max(0.0, peak - HBM_BYTES)
    cost = max(terms["compute_s"], terms["memory_s"],
               terms["collective_s"]) + OVERFLOW_LAMBDA * overflow
    return DistResult(variant=variant, terms={
        **{k: terms[k] for k in ("compute_s", "memory_s", "collective_s")},
        "hbm_peak_gib": peak / 2**30,
    }, cost=cost, record=record)


def tune_distribution(arch: str, shape: str, run_cell_fn,
                      structural_terms_fn,
                      space: Optional[List[Dict]] = None,
                      kind: str = "train",
                      base_accum: int = 16) -> Tuple[DistResult, List[DistResult]]:
    """Exhaustive static search; returns (best, all evaluated)."""
    space = space or default_space(kind, base_accum)
    results = []
    for variant in space:
        try:
            results.append(evaluate_variant(arch, shape, variant, run_cell_fn,
                                            structural_terms_fn))
        except Exception as e:  # noqa: BLE001 — a variant may not compile
            results.append(DistResult(variant=variant, terms={},
                                      cost=float("inf"),
                                      record={"status": "error",
                                              "error": str(e)[:300]}))
    best = min(results, key=lambda r: r.cost)
    return best, results
