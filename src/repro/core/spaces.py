"""Transformation candidate spaces T_e (paper Eq. 1) + TIR instantiation.

A ``Space`` defines the discrete schedule knobs for one tensor operator and
materialises a chosen configuration into (Program TIR, ScheduleMeta). ES
operates on a continuous θ that ``decode`` buckets into knob choices.

Spaces provided:
  * ``MatmulSpace``      — C[M,N] += A[M,K]·B[K,N]; TPU: Pallas-style grid
    (block loops + MXU tensor nest + double buffering); CPU: cache tiling +
    vectorised j + unrolled i (the paper's conv2d/dense CPU schedule family).
  * ``BatchMatmulSpace`` — adds a batch grid dimension.
  * ``Conv2dSpace``      — direct NHWC conv, tiled over (oc, oh·ow), reduction
    over (kh, kw, ic); CPU + TPU (im2col-style MXU mapping).
  * ``DepthwiseConv2dSpace`` — per-channel conv (VPU-only on TPU).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, Iterator, List, Sequence, Tuple

import numpy as np

from repro.core.cost_model import ScheduleMeta
from repro.core.tir import Access, Compute, LinExpr, Loop, Program, TensorDecl


def _pow2_choices(lo: int, hi: int, cap: int) -> List[int]:
    out = []
    v = lo
    while v <= min(hi, cap):
        out.append(v)
        v *= 2
    return out or [min(lo, cap)]


def _divisors_pow2(n: int, lo: int, hi: int) -> List[int]:
    return [d for d in _pow2_choices(lo, hi, n) if n % d == 0] or [n]


class Space:
    """Base: a dict of named discrete knobs."""

    name: str = "space"

    def __init__(self) -> None:
        self.knobs: Dict[str, List] = {}

    @property
    def dim(self) -> int:
        return len(self.knobs)

    def decode(self, theta: np.ndarray) -> Dict:
        cfg = {}
        for (name, choices), t in zip(self.knobs.items(), theta):
            # map R -> index via round+clip; theta 0 = centre of the list
            idx = int(round(float(t) + (len(choices) - 1) / 2.0))
            cfg[name] = choices[max(0, min(len(choices) - 1, idx))]
        return cfg

    def default_config(self) -> Dict:
        return {k: v[len(v) // 2] for k, v in self.knobs.items()}

    def enumerate(self, limit: int = 10_000) -> Iterator[Dict]:
        names = list(self.knobs)
        for i, combo in enumerate(itertools.product(*self.knobs.values())):
            if i >= limit:
                return
            yield dict(zip(names, combo))

    def size(self) -> int:
        n = 1
        for v in self.knobs.values():
            n *= len(v)
        return n

    def instantiate(self, cfg: Dict) -> Tuple[Program, ScheduleMeta]:
        raise NotImplementedError

    def signature(self) -> str:
        """Canonical operator signature, e.g. ``matmul[K=256,M=256,N=256,
        dtype_bytes=4]`` — the ``op`` key of `repro.tuna` schedule records.

        Built from the scalar attributes that define the operator *instance*
        (shapes, dtype width), not the schedule knobs and not ``target_kind``
        (the record's ``target`` field already pins the hardware)."""
        attrs = {
            k: v for k, v in vars(self).items()
            if not k.startswith("_") and k not in ("knobs", "target_kind")
            and isinstance(v, int)
        }
        inner = ",".join(f"{k}={attrs[k]}" for k in sorted(attrs))
        return f"{self.name}[{inner}]"


# ---------------------------------------------------------------------------
# Matmul
# ---------------------------------------------------------------------------


class MatmulSpace(Space):
    name = "matmul"

    def __init__(self, M: int, N: int, K: int, dtype_bytes: int = 4,
                 target_kind: str = "tpu"):
        super().__init__()
        self.M, self.N, self.K = M, N, K
        self.dtype_bytes = dtype_bytes
        self.target_kind = target_kind
        if target_kind == "tpu":
            self.knobs = {
                "bm": _divisors_pow2(M, 8, 512),
                "bn": _divisors_pow2(N, 128, 1024),
                "bk": _divisors_pow2(K, 128, 2048),
                "double_buffer": [False, True],
            }
        else:
            self.knobs = {
                "bm": _divisors_pow2(M, 4, 256),
                "bn": _divisors_pow2(N, 8, 512),
                "bk": _divisors_pow2(K, 8, 512),
                "order": ["ikj", "kij"],
                "unroll_i": [1, 2, 4],
            }

    # -- TPU: grid block loops + MXU nest --------------------------------
    def _tpu_program(self, cfg) -> Tuple[Program, ScheduleMeta]:
        M, N, K = self.M, self.N, self.K
        bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
        gm, gn, gk = M // bm, N // bn, K // bk
        A = TensorDecl("A", (M, K), self.dtype_bytes)
        B = TensorDecl("B", (K, N), self.dtype_bytes)
        C = TensorDecl("C", (M, N), self.dtype_bytes)

        stmt = Compute(
            "fma",
            output=Access("C", (
                LinExpr.of(("gm", bm), ("tm", 1)),
                LinExpr.of(("gn", bn), ("tn", 1)),
            ), is_store=True),
            inputs=(
                Access("A", (LinExpr.of(("gm", bm), ("tm", 1)),
                             LinExpr.of(("gk", bk), ("tk", 1)))),
                Access("B", (LinExpr.of(("gk", bk), ("tk", 1)),
                             LinExpr.of(("gn", bn), ("tn", 1)))),
            ),
        )
        nest = Loop("tm", bm, (Loop("tn", bn, (Loop("tk", bk, (stmt,),
                    "tensor.k"),), "tensor.n"),), "tensor.m")
        kloop = Loop("gk", gk, (nest,), "block")  # grid reduction dim
        grid_n = Loop("gn", gn, (kloop,), "serial")
        grid_m = Loop("gm", gm, (grid_n,), "serial")
        prog = Program((A, B, C), (grid_m,), name=f"matmul_{M}x{N}x{K}")
        tile_bytes = (bm * bk + bk * bn + bm * bn) * self.dtype_bytes
        meta = ScheduleMeta(
            grid_size=gm * gn * gk,
            double_buffer=cfg["double_buffer"],
            parallel_extent=gm * gn,
            vmem_tile_bytes=tile_bytes,
        )
        return prog, meta

    # -- CPU: cache tiling + vector j ------------------------------------
    def _cpu_program(self, cfg) -> Tuple[Program, ScheduleMeta]:
        M, N, K = self.M, self.N, self.K
        bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
        u = min(cfg["unroll_i"], bm)
        A = TensorDecl("A", (M, K), self.dtype_bytes)
        B = TensorDecl("B", (K, N), self.dtype_bytes)
        C = TensorDecl("C", (M, N), self.dtype_bytes)
        stmt = Compute(
            "fma",
            output=Access("C", (
                LinExpr.of(("it", bm), ("i", 1)),
                LinExpr.of(("jt", bn), ("j", 1)),
            ), is_store=True),
            inputs=(
                Access("A", (LinExpr.of(("it", bm), ("i", 1)),
                             LinExpr.of(("kt", bk), ("k", 1)))),
                Access("B", (LinExpr.of(("kt", bk), ("k", 1)),
                             LinExpr.of(("jt", bn), ("j", 1)))),
            ),
        )
        jv = Loop("j", bn, (stmt,), "vector")
        if cfg["order"] == "ikj":
            inner = Loop("i", bm // u, (Loop("iu", u, (Loop("k", bk, (jv,),
                         "serial"),), "unroll"),), "serial")
        else:  # kij
            inner = Loop("k", bk, (Loop("i", bm // u, (Loop("iu", u, (jv,),
                         "unroll"),), "serial"),), "serial")
        kt = Loop("kt", K // bk, (inner,), "serial")
        jt = Loop("jt", N // bn, (kt,), "serial")
        it = Loop("it", M // bm, (jt,), "serial")
        prog = Program((A, B, C), (it,), name=f"matmul_{M}x{N}x{K}")
        meta = ScheduleMeta(
            grid_size=(M // bm) * (N // bn) * (K // bk),  # block dispatches
            parallel_extent=M // bm,
            vmem_tile_bytes=0,
        )
        return prog, meta

    def instantiate(self, cfg):
        if self.target_kind == "tpu":
            return self._tpu_program(cfg)
        return self._cpu_program(cfg)


class BatchMatmulSpace(MatmulSpace):
    name = "batch_matmul"

    def __init__(self, Bsz: int, M: int, N: int, K: int, dtype_bytes: int = 4,
                 target_kind: str = "tpu"):
        super().__init__(M, N, K, dtype_bytes, target_kind)
        self.Bsz = Bsz

    def instantiate(self, cfg):
        prog, meta = super().instantiate(cfg)
        # wrap in a parallel batch loop; accesses gain a batch index
        def add_batch(node):
            if isinstance(node, Loop):
                return dataclasses.replace(
                    node, body=tuple(add_batch(ch) for ch in node.body)
                )
            out = dataclasses.replace(
                node,
                output=_with_batch(node.output),
                inputs=tuple(_with_batch(a) for a in node.inputs),
            )
            return out

        def _with_batch(acc: Access) -> Access:
            return Access(acc.tensor, (LinExpr.var("b"),) + acc.indices,
                          acc.is_store)

        tensors = tuple(
            TensorDecl(t.name, (self.Bsz,) + t.shape, t.dtype_bytes)
            for t in prog.tensors
        )
        roots = tuple(Loop("b", self.Bsz, (add_batch(r),), "parallel")
                      for r in prog.roots)
        prog = Program(tensors, roots, name=f"bmm_{self.Bsz}x{self.M}")
        meta = dataclasses.replace(
            meta,
            grid_size=meta.grid_size * self.Bsz,
            parallel_extent=meta.parallel_extent * self.Bsz,
        )
        return prog, meta


# ---------------------------------------------------------------------------
# Conv2d (NHWC, direct)
# ---------------------------------------------------------------------------


class Conv2dSpace(Space):
    name = "conv2d"

    def __init__(self, N: int, H: int, W: int, Cin: int, Cout: int,
                 KH: int = 3, KW: int = 3, dtype_bytes: int = 4,
                 target_kind: str = "cpu"):
        super().__init__()
        self.N, self.H, self.W = N, H, W
        self.Cin, self.Cout, self.KH, self.KW = Cin, Cout, KH, KW
        self.dtype_bytes = dtype_bytes
        self.target_kind = target_kind
        self.knobs = {
            "b_oc": _divisors_pow2(Cout, 8, 256),
            "b_ow": _divisors_pow2(W, 2, 64),
            "b_ic": _divisors_pow2(Cin, 8, 256),
        }

    def instantiate(self, cfg):
        N, H, W = self.N, self.H, self.W
        Cin, Cout, KH, KW = self.Cin, self.Cout, self.KH, self.KW
        b_oc, b_ow, b_ic = cfg["b_oc"], cfg["b_ow"], cfg["b_ic"]
        X = TensorDecl("X", (N, H + KH - 1, W + KW - 1, Cin), self.dtype_bytes)
        Wt = TensorDecl("W", (KH, KW, Cin, Cout), self.dtype_bytes)
        Y = TensorDecl("Y", (N, H, W, Cout), self.dtype_bytes)
        # Y[n, oh, owt*b+ow, oct*b+oc] += X[n, oh+kh, owt*b+ow+kw, ict*b+ic]
        #                                 * W[kh, kw, ict*b+ic, oct*b+oc]
        stmt = Compute(
            "fma",
            output=Access("Y", (
                LinExpr.var("n"), LinExpr.var("oh"),
                LinExpr.of(("owt", b_ow), ("ow", 1)),
                LinExpr.of(("oct", b_oc), ("oc", 1)),
            ), is_store=True),
            inputs=(
                Access("X", (
                    LinExpr.var("n"),
                    LinExpr.of(("oh", 1), ("kh", 1)),
                    LinExpr.of(("owt", b_ow), ("ow", 1), ("kw", 1)),
                    LinExpr.of(("ict", b_ic), ("ic", 1)),
                )),
                Access("W", (
                    LinExpr.var("kh"), LinExpr.var("kw"),
                    LinExpr.of(("ict", b_ic), ("ic", 1)),
                    LinExpr.of(("oct", b_oc), ("oc", 1)),
                )),
            ),
        )
        if self.target_kind == "tpu":
            # im2col mapping: (ow x ic) micro-tile on the MXU
            nest = Loop("ow", b_ow, (Loop("oc", b_oc, (Loop(
                "ic", b_ic, (stmt,), "tensor.k"),), "tensor.n"),), "tensor.m")
        else:
            nest = Loop("ow", b_ow, (Loop("ic", b_ic, (Loop(
                "oc", b_oc, (stmt,), "vector"),), "serial"),), "serial")
        kw_l = Loop("kw", KW, (nest,), "serial")
        kh_l = Loop("kh", KH, (kw_l,), "serial")
        ict = Loop("ict", Cin // b_ic, (kh_l,),
                   "block" if self.target_kind == "tpu" else "serial")
        owt = Loop("owt", W // b_ow, (ict,), "serial")
        oct_ = Loop("oct", Cout // b_oc, (owt,), "serial")
        oh_l = Loop("oh", H, (oct_,), "serial")
        n_l = Loop("n", N, (oh_l,), "parallel")
        prog = Program((X, Wt, Y), (n_l,),
                       name=f"conv2d_{N}x{H}x{W}x{Cin}x{Cout}")
        tile = (b_ow * b_ic + b_ic * b_oc + b_ow * b_oc) * self.dtype_bytes
        meta = ScheduleMeta(
            grid_size=N * H * (Cout // b_oc) * (W // b_ow),
            parallel_extent=N * H,
            vmem_tile_bytes=tile,
            double_buffer=False,
        )
        return prog, meta


class DepthwiseConv2dSpace(Space):
    name = "depthwise_conv2d"

    def __init__(self, N: int, H: int, W: int, C: int, KH: int = 3,
                 KW: int = 3, dtype_bytes: int = 4, target_kind: str = "cpu"):
        super().__init__()
        self.N, self.H, self.W, self.C = N, H, W, C
        self.KH, self.KW = KH, KW
        self.dtype_bytes = dtype_bytes
        self.target_kind = target_kind
        self.knobs = {
            "b_c": _divisors_pow2(C, 8, 512),
            "b_ow": _divisors_pow2(W, 2, 64),
        }

    def instantiate(self, cfg):
        N, H, W, C = self.N, self.H, self.W, self.C
        KH, KW = self.KH, self.KW
        b_c, b_ow = cfg["b_c"], cfg["b_ow"]
        X = TensorDecl("X", (N, H + KH - 1, W + KW - 1, C), self.dtype_bytes)
        Wt = TensorDecl("W", (KH, KW, C), self.dtype_bytes)
        Y = TensorDecl("Y", (N, H, W, C), self.dtype_bytes)
        stmt = Compute(
            "fma",
            output=Access("Y", (
                LinExpr.var("n"), LinExpr.var("oh"),
                LinExpr.of(("owt", b_ow), ("ow", 1)),
                LinExpr.of(("ct", b_c), ("c", 1)),
            ), is_store=True),
            inputs=(
                Access("X", (
                    LinExpr.var("n"), LinExpr.of(("oh", 1), ("kh", 1)),
                    LinExpr.of(("owt", b_ow), ("ow", 1), ("kw", 1)),
                    LinExpr.of(("ct", b_c), ("c", 1)),
                )),
                Access("W", (LinExpr.var("kh"), LinExpr.var("kw"),
                             LinExpr.of(("ct", b_c), ("c", 1)))),
            ),
        )
        cv = Loop("c", b_c, (stmt,), "vector")
        ow_l = Loop("ow", b_ow, (cv,), "serial")
        kw_l = Loop("kw", KW, (ow_l,), "serial")
        kh_l = Loop("kh", KH, (kw_l,), "serial")
        ct = Loop("ct", C // b_c, (kh_l,),
                  "block" if self.target_kind == "tpu" else "serial")
        owt = Loop("owt", W // b_ow, (ct,), "serial")
        oh_l = Loop("oh", H, (owt,), "serial")
        n_l = Loop("n", N, (oh_l,), "parallel")
        prog = Program((X, Wt, Y), (n_l,), name=f"dwconv_{N}x{H}x{W}x{C}")
        meta = ScheduleMeta(
            grid_size=N * H * (C // b_c),
            parallel_extent=N * H,
            vmem_tile_bytes=(2 * b_ow * b_c + KH * KW * b_c) * self.dtype_bytes,
        )
        return prog, meta
