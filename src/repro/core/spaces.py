"""Transformation candidate spaces T_e (paper Eq. 1) + TIR instantiation.

A ``Space`` defines the discrete schedule knobs for one tensor operator and
materialises a chosen configuration into (Program TIR, ScheduleMeta). ES
operates on a continuous θ that ``decode`` buckets into knob choices.

This module registers the paper's four §V.B operator families with the
declarative registry in :mod:`repro.core.op_registry` — each is one
:class:`~repro.core.op_registry.OpDef` (attrs, knob generator, TIR builder,
presets) — and keeps the historical ``Space`` subclasses as thin constructor
shims over those defs:

  * ``MatmulSpace``      — C[M,N] += A[M,K]·B[K,N]; TPU: Pallas-style grid
    (block loops + MXU tensor nest + double buffering); CPU/GPU: cache tiling
    + vectorised j + unrolled i (the paper's conv2d/dense CPU schedule
    family).
  * ``BatchMatmulSpace`` — adds a batch grid dimension.
  * ``Conv2dSpace``      — direct NHWC conv, tiled over (oc, oh·ow), reduction
    over (kh, kw, ic); CPU + TPU (im2col-style MXU mapping).
  * ``DepthwiseConv2dSpace`` — per-channel conv (VPU-only on TPU).

Model-zoo families (MoE dispatch, SSM scan, mLSTM chunk, flash/GQA
attention) register in :mod:`repro.core.zoo` using the shared builders here.
Signatures of the four legacy families are byte-identical to the
pre-registry format.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.core.cost_model import ScheduleMeta
from repro.core.op_registry import (
    DTYPE_BY_BYTES,
    AttrSpec,
    BundleSkip,
    BundleSpec,
    KnobFeature,
    OpDef,
    Preset,
    RegistrySpace,
    Space,
    register,
)
from repro.core.tir import Access, Compute, LinExpr, Loop, Program, TensorDecl

__all__ = [
    "Space",
    "MatmulSpace",
    "BatchMatmulSpace",
    "Conv2dSpace",
    "DepthwiseConv2dSpace",
]


def _pow2_choices(lo: int, hi: int, cap: int) -> List[int]:
    out = []
    v = lo
    while v <= min(hi, cap):
        out.append(v)
        v *= 2
    return out or [min(lo, cap)]


def _divisors_pow2(n: int, lo: int, hi: int) -> List[int]:
    return [d for d in _pow2_choices(lo, hi, n) if n % d == 0] or [n]


def _wrap_parallel(prog: Program, meta: ScheduleMeta,
                   dims: Sequence[Tuple[str, int]],
                   name: str) -> Tuple[Program, ScheduleMeta]:
    """Wrap a program in outer parallel grid loops (batch / expert / head):
    every tensor gains the leading dims, every access the matching indices."""

    def _idx(acc: Access) -> Access:
        lead = tuple(LinExpr.var(v) for v, _ in dims)
        return Access(acc.tensor, lead + acc.indices, acc.is_store)

    def _add(node):
        if isinstance(node, Loop):
            return dataclasses.replace(
                node, body=tuple(_add(ch) for ch in node.body))
        return dataclasses.replace(
            node, output=_idx(node.output),
            inputs=tuple(_idx(a) for a in node.inputs))

    extents = tuple(e for _, e in dims)
    tensors = tuple(TensorDecl(t.name, extents + t.shape, t.dtype_bytes)
                    for t in prog.tensors)

    def _nest(root):
        body = (_add(root),)
        for var, extent in reversed(dims):
            body = (Loop(var, extent, body, "parallel"),)
        return body[0]

    total = 1
    for e in extents:
        total *= e
    wrapped = Program(tensors, tuple(_nest(r) for r in prog.roots), name=name)
    meta = dataclasses.replace(
        meta,
        grid_size=meta.grid_size * total,
        parallel_extent=meta.parallel_extent * total,
    )
    return wrapped, meta


# ---------------------------------------------------------------------------
# Matmul family
# ---------------------------------------------------------------------------


def _matmul_knobs(attrs: Dict, kind: str) -> Dict[str, List]:
    M, N, K = attrs["M"], attrs["N"], attrs["K"]
    if kind == "tpu":
        return {
            "bm": _divisors_pow2(M, 8, 512),
            "bn": _divisors_pow2(N, 128, 1024),
            "bk": _divisors_pow2(K, 128, 2048),
            "double_buffer": [False, True],
        }
    return {
        "bm": _divisors_pow2(M, 4, 256),
        "bn": _divisors_pow2(N, 8, 512),
        "bk": _divisors_pow2(K, 8, 512),
        "order": ["ikj", "kij"],
        "unroll_i": [1, 2, 4],
    }


def _matmul_tpu(attrs: Dict, cfg: Dict) -> Tuple[Program, ScheduleMeta]:
    """TPU: grid block loops + MXU nest."""
    M, N, K, db = attrs["M"], attrs["N"], attrs["K"], attrs["dtype_bytes"]
    bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
    gm, gn, gk = M // bm, N // bn, K // bk
    A = TensorDecl("A", (M, K), db)
    B = TensorDecl("B", (K, N), db)
    C = TensorDecl("C", (M, N), db)

    stmt = Compute(
        "fma",
        output=Access("C", (
            LinExpr.of(("gm", bm), ("tm", 1)),
            LinExpr.of(("gn", bn), ("tn", 1)),
        ), is_store=True),
        inputs=(
            Access("A", (LinExpr.of(("gm", bm), ("tm", 1)),
                         LinExpr.of(("gk", bk), ("tk", 1)))),
            Access("B", (LinExpr.of(("gk", bk), ("tk", 1)),
                         LinExpr.of(("gn", bn), ("tn", 1)))),
        ),
    )
    nest = Loop("tm", bm, (Loop("tn", bn, (Loop("tk", bk, (stmt,),
                "tensor.k"),), "tensor.n"),), "tensor.m")
    kloop = Loop("gk", gk, (nest,), "block")  # grid reduction dim
    grid_n = Loop("gn", gn, (kloop,), "serial")
    grid_m = Loop("gm", gm, (grid_n,), "serial")
    prog = Program((A, B, C), (grid_m,), name=f"matmul_{M}x{N}x{K}")
    tile_bytes = (bm * bk + bk * bn + bm * bn) * db
    meta = ScheduleMeta(
        grid_size=gm * gn * gk,
        double_buffer=cfg["double_buffer"],
        parallel_extent=gm * gn,
        vmem_tile_bytes=tile_bytes,
    )
    return prog, meta


def _matmul_cpu(attrs: Dict, cfg: Dict) -> Tuple[Program, ScheduleMeta]:
    """CPU/GPU SIMD: cache tiling + vector j (+ unrolled i)."""
    M, N, K, db = attrs["M"], attrs["N"], attrs["K"], attrs["dtype_bytes"]
    bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
    u = min(cfg["unroll_i"], bm)
    A = TensorDecl("A", (M, K), db)
    B = TensorDecl("B", (K, N), db)
    C = TensorDecl("C", (M, N), db)
    stmt = Compute(
        "fma",
        output=Access("C", (
            LinExpr.of(("it", bm), ("i", 1)),
            LinExpr.of(("jt", bn), ("j", 1)),
        ), is_store=True),
        inputs=(
            Access("A", (LinExpr.of(("it", bm), ("i", 1)),
                         LinExpr.of(("kt", bk), ("k", 1)))),
            Access("B", (LinExpr.of(("kt", bk), ("k", 1)),
                         LinExpr.of(("jt", bn), ("j", 1)))),
        ),
    )
    jv = Loop("j", bn, (stmt,), "vector")
    if cfg["order"] == "ikj":
        inner = Loop("i", bm // u, (Loop("iu", u, (Loop("k", bk, (jv,),
                     "serial"),), "unroll"),), "serial")
    else:  # kij
        inner = Loop("k", bk, (Loop("i", bm // u, (Loop("iu", u, (jv,),
                     "unroll"),), "serial"),), "serial")
    kt = Loop("kt", K // bk, (inner,), "serial")
    jt = Loop("jt", N // bn, (kt,), "serial")
    it = Loop("it", M // bm, (jt,), "serial")
    prog = Program((A, B, C), (it,), name=f"matmul_{M}x{N}x{K}")
    meta = ScheduleMeta(
        grid_size=(M // bm) * (N // bn) * (K // bk),  # block dispatches
        parallel_extent=M // bm,
        vmem_tile_bytes=0,
    )
    return prog, meta


def _build_matmul(attrs: Dict, cfg: Dict,
                  kind: str) -> Tuple[Program, ScheduleMeta]:
    if kind == "tpu":
        return _matmul_tpu(attrs, cfg)
    return _matmul_cpu(attrs, cfg)


def _matmul_bundle(attrs: Dict, config: Dict) -> BundleSpec:
    dtype = DTYPE_BY_BYTES.get(attrs["dtype_bytes"])
    if dtype is None:
        raise BundleSkip("unsupported dtype_bytes")
    if not {"bm", "bn", "bk"} <= set(config):
        raise BundleSkip("no TPU block schedule in config (cpu-knob record)")
    M, N, K = attrs["M"], attrs["N"], attrs["K"]
    return BundleSpec("matmul",
                      (((M, K), dtype), ((K, N), dtype)), {})


# the choice superset ("ijk" included) pins the historical learned-ranker
# one-hot layout even though the cpu knob generator only offers ikj/kij
MATMUL_KNOB_FEATURES = (
    KnobFeature("bm", "log2"),
    KnobFeature("bn", "log2"),
    KnobFeature("bk", "log2"),
    KnobFeature("unroll_i", "raw"),
    KnobFeature("double_buffer", "flag"),
    KnobFeature("order", "choice", ("ikj", "kij", "ijk")),
)

MATMUL_DEF = register(OpDef(
    name="matmul",
    attrs=(AttrSpec("M"), AttrSpec("N"), AttrSpec("K"),
           AttrSpec("dtype_bytes", int, 4)),
    knob_fn=_matmul_knobs,
    build_fn=_build_matmul,
    bundle_fn=_matmul_bundle,
    knob_features=MATMUL_KNOB_FEATURES,
    presets={
        "dense_256": Preset({"M": 256, "N": 256, "K": 256}, "cpu"),
        "dense_512": Preset({"M": 512, "N": 512, "K": 512}, "cpu"),
        # bf16 TPU matmul shapes the kernel block-spec picker asks for at
        # trace time — tuning these warms the DB tuned_matmul_blocks consults
        "matmul_1024_bf16": Preset(
            {"M": 1024, "N": 1024, "K": 1024, "dtype_bytes": 2}, "tpu"),
        "matmul_2048_bf16": Preset(
            {"M": 2048, "N": 2048, "K": 2048, "dtype_bytes": 2}, "tpu"),
        "matmul_4096_bf16": Preset(
            {"M": 4096, "N": 4096, "K": 4096, "dtype_bytes": 2}, "tpu"),
    },
    doc="C[M,N] += A[M,K] @ B[K,N]",
))


class MatmulSpace(RegistrySpace):
    name = "matmul"

    def __init__(self, M: int, N: int, K: int, dtype_bytes: int = 4,
                 target_kind: str = "tpu"):
        RegistrySpace.__init__(
            self, MATMUL_DEF,
            {"M": M, "N": N, "K": K, "dtype_bytes": dtype_bytes},
            target_kind)


MATMUL_DEF.space_cls = MatmulSpace


def _build_batch_matmul(attrs: Dict, cfg: Dict,
                        kind: str) -> Tuple[Program, ScheduleMeta]:
    prog, meta = _build_matmul(attrs, cfg, kind)
    return _wrap_parallel(prog, meta, (("b", attrs["Bsz"]),),
                          f"bmm_{attrs['Bsz']}x{attrs['M']}")


BATCH_MATMUL_DEF = register(OpDef(
    name="batch_matmul",
    attrs=(AttrSpec("Bsz"), AttrSpec("M"), AttrSpec("N"), AttrSpec("K"),
           AttrSpec("dtype_bytes", int, 4)),
    knob_fn=_matmul_knobs,
    build_fn=_build_batch_matmul,
    knob_features=MATMUL_KNOB_FEATURES,
    presets={
        "batch_matmul": Preset(
            {"Bsz": 8, "M": 128, "N": 128, "K": 64}, "cpu"),
    },
    doc="C[b,M,N] += A[b,M,K] @ B[b,K,N]",
))


class BatchMatmulSpace(MatmulSpace):
    name = "batch_matmul"

    def __init__(self, Bsz: int, M: int, N: int, K: int,
                 dtype_bytes: int = 4, target_kind: str = "tpu"):
        RegistrySpace.__init__(
            self, BATCH_MATMUL_DEF,
            {"Bsz": Bsz, "M": M, "N": N, "K": K,
             "dtype_bytes": dtype_bytes},
            target_kind)


BATCH_MATMUL_DEF.space_cls = BatchMatmulSpace


# ---------------------------------------------------------------------------
# Conv2d (NHWC, direct)
# ---------------------------------------------------------------------------


def _conv2d_knobs(attrs: Dict, kind: str) -> Dict[str, List]:
    return {
        "b_oc": _divisors_pow2(attrs["Cout"], 8, 256),
        "b_ow": _divisors_pow2(attrs["W"], 2, 64),
        "b_ic": _divisors_pow2(attrs["Cin"], 8, 256),
    }


def _build_conv2d(attrs: Dict, cfg: Dict,
                  kind: str) -> Tuple[Program, ScheduleMeta]:
    N, H, W = attrs["N"], attrs["H"], attrs["W"]
    Cin, Cout = attrs["Cin"], attrs["Cout"]
    KH, KW, db = attrs["KH"], attrs["KW"], attrs["dtype_bytes"]
    b_oc, b_ow, b_ic = cfg["b_oc"], cfg["b_ow"], cfg["b_ic"]
    X = TensorDecl("X", (N, H + KH - 1, W + KW - 1, Cin), db)
    Wt = TensorDecl("W", (KH, KW, Cin, Cout), db)
    Y = TensorDecl("Y", (N, H, W, Cout), db)
    # Y[n, oh, owt*b+ow, oct*b+oc] += X[n, oh+kh, owt*b+ow+kw, ict*b+ic]
    #                                 * W[kh, kw, ict*b+ic, oct*b+oc]
    stmt = Compute(
        "fma",
        output=Access("Y", (
            LinExpr.var("n"), LinExpr.var("oh"),
            LinExpr.of(("owt", b_ow), ("ow", 1)),
            LinExpr.of(("oct", b_oc), ("oc", 1)),
        ), is_store=True),
        inputs=(
            Access("X", (
                LinExpr.var("n"),
                LinExpr.of(("oh", 1), ("kh", 1)),
                LinExpr.of(("owt", b_ow), ("ow", 1), ("kw", 1)),
                LinExpr.of(("ict", b_ic), ("ic", 1)),
            )),
            Access("W", (
                LinExpr.var("kh"), LinExpr.var("kw"),
                LinExpr.of(("ict", b_ic), ("ic", 1)),
                LinExpr.of(("oct", b_oc), ("oc", 1)),
            )),
        ),
    )
    if kind == "tpu":
        # im2col mapping: (ow x ic) micro-tile on the MXU
        nest = Loop("ow", b_ow, (Loop("oc", b_oc, (Loop(
            "ic", b_ic, (stmt,), "tensor.k"),), "tensor.n"),), "tensor.m")
    else:
        nest = Loop("ow", b_ow, (Loop("ic", b_ic, (Loop(
            "oc", b_oc, (stmt,), "vector"),), "serial"),), "serial")
    kw_l = Loop("kw", KW, (nest,), "serial")
    kh_l = Loop("kh", KH, (kw_l,), "serial")
    ict = Loop("ict", Cin // b_ic, (kh_l,),
               "block" if kind == "tpu" else "serial")
    owt = Loop("owt", W // b_ow, (ict,), "serial")
    oct_ = Loop("oct", Cout // b_oc, (owt,), "serial")
    oh_l = Loop("oh", H, (oct_,), "serial")
    n_l = Loop("n", N, (oh_l,), "parallel")
    prog = Program((X, Wt, Y), (n_l,),
                   name=f"conv2d_{N}x{H}x{W}x{Cin}x{Cout}")
    tile = (b_ow * b_ic + b_ic * b_oc + b_ow * b_oc) * db
    meta = ScheduleMeta(
        grid_size=N * H * (Cout // b_oc) * (W // b_ow),
        parallel_extent=N * H,
        vmem_tile_bytes=tile,
        double_buffer=False,
    )
    return prog, meta


CONV2D_DEF = register(OpDef(
    name="conv2d",
    attrs=(AttrSpec("N"), AttrSpec("H"), AttrSpec("W"),
           AttrSpec("Cin"), AttrSpec("Cout"),
           AttrSpec("KH", int, 3), AttrSpec("KW", int, 3),
           AttrSpec("dtype_bytes", int, 4)),
    knob_fn=_conv2d_knobs,
    build_fn=_build_conv2d,
    knob_features=(
        KnobFeature("b_oc", "log2"),
        KnobFeature("b_ow", "log2"),
        KnobFeature("b_ic", "log2"),
    ),
    presets={
        "conv2d": Preset({"N": 1, "H": 14, "W": 14, "Cin": 256,
                          "Cout": 256}, "cpu"),
    },
    doc="direct NHWC conv2d",
))


class Conv2dSpace(RegistrySpace):
    name = "conv2d"

    def __init__(self, N: int, H: int, W: int, Cin: int, Cout: int,
                 KH: int = 3, KW: int = 3, dtype_bytes: int = 4,
                 target_kind: str = "cpu"):
        RegistrySpace.__init__(
            self, CONV2D_DEF,
            {"N": N, "H": H, "W": W, "Cin": Cin, "Cout": Cout,
             "KH": KH, "KW": KW, "dtype_bytes": dtype_bytes},
            target_kind)


CONV2D_DEF.space_cls = Conv2dSpace


def _depthwise_knobs(attrs: Dict, kind: str) -> Dict[str, List]:
    return {
        "b_c": _divisors_pow2(attrs["C"], 8, 512),
        "b_ow": _divisors_pow2(attrs["W"], 2, 64),
    }


def _build_depthwise(attrs: Dict, cfg: Dict,
                     kind: str) -> Tuple[Program, ScheduleMeta]:
    N, H, W, C = attrs["N"], attrs["H"], attrs["W"], attrs["C"]
    KH, KW, db = attrs["KH"], attrs["KW"], attrs["dtype_bytes"]
    b_c, b_ow = cfg["b_c"], cfg["b_ow"]
    X = TensorDecl("X", (N, H + KH - 1, W + KW - 1, C), db)
    Wt = TensorDecl("W", (KH, KW, C), db)
    Y = TensorDecl("Y", (N, H, W, C), db)
    stmt = Compute(
        "fma",
        output=Access("Y", (
            LinExpr.var("n"), LinExpr.var("oh"),
            LinExpr.of(("owt", b_ow), ("ow", 1)),
            LinExpr.of(("ct", b_c), ("c", 1)),
        ), is_store=True),
        inputs=(
            Access("X", (
                LinExpr.var("n"), LinExpr.of(("oh", 1), ("kh", 1)),
                LinExpr.of(("owt", b_ow), ("ow", 1), ("kw", 1)),
                LinExpr.of(("ct", b_c), ("c", 1)),
            )),
            Access("W", (LinExpr.var("kh"), LinExpr.var("kw"),
                         LinExpr.of(("ct", b_c), ("c", 1)))),
        ),
    )
    cv = Loop("c", b_c, (stmt,), "vector")
    ow_l = Loop("ow", b_ow, (cv,), "serial")
    kw_l = Loop("kw", KW, (ow_l,), "serial")
    kh_l = Loop("kh", KH, (kw_l,), "serial")
    ct = Loop("ct", C // b_c, (kh_l,),
              "block" if kind == "tpu" else "serial")
    owt = Loop("owt", W // b_ow, (ct,), "serial")
    oh_l = Loop("oh", H, (owt,), "serial")
    n_l = Loop("n", N, (oh_l,), "parallel")
    prog = Program((X, Wt, Y), (n_l,), name=f"dwconv_{N}x{H}x{W}x{C}")
    meta = ScheduleMeta(
        grid_size=N * H * (C // b_c),
        parallel_extent=N * H,
        vmem_tile_bytes=(2 * b_ow * b_c + KH * KW * b_c) * db,
    )
    return prog, meta


DEPTHWISE_DEF = register(OpDef(
    name="depthwise_conv2d",
    attrs=(AttrSpec("N"), AttrSpec("H"), AttrSpec("W"), AttrSpec("C"),
           AttrSpec("KH", int, 3), AttrSpec("KW", int, 3),
           AttrSpec("dtype_bytes", int, 4)),
    knob_fn=_depthwise_knobs,
    build_fn=_build_depthwise,
    knob_features=(
        KnobFeature("b_c", "log2"),
        KnobFeature("b_ow", "log2"),
    ),
    presets={
        "depthwise_conv2d": Preset({"N": 1, "H": 28, "W": 28, "C": 128},
                                   "cpu"),
    },
    doc="per-channel NHWC conv (VPU-only on TPU)",
))


class DepthwiseConv2dSpace(RegistrySpace):
    name = "depthwise_conv2d"

    def __init__(self, N: int, H: int, W: int, C: int, KH: int = 3,
                 KW: int = 3, dtype_bytes: int = 4,
                 target_kind: str = "cpu"):
        RegistrySpace.__init__(
            self, DEPTHWISE_DEF,
            {"N": N, "H": H, "W": W, "C": C, "KH": KH, "KW": KW,
             "dtype_bytes": dtype_bytes},
            target_kind)


DEPTHWISE_DEF.space_cls = DepthwiseConv2dSpace
