"""Loop-nest tensor IR — the "program IR" side of Tuna's joint analysis.

This is a deliberately small TIR in the spirit of TVM's TIR: a tree of
``Loop`` nodes whose leaves are ``Compute`` statements made of affine
``Access``es. It preserves the complete loop structure (trip counts, loop
kinds) which the low-level code (VISA / HLO text) does not — exactly the split
the paper's Algorithm 1 exploits.

Affine accesses: every tensor dimension is indexed by a linear form
``Σ coeff_i * var_i + const``. This covers all programs in our transformation
spaces (tiled matmul / conv / attention / elementwise) and lets the locality
model (Alg. 2) compute exact footprints for regular tilings without ISL.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple, Union

# --------------------------------------------------------------------------
# Linear index expressions
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinExpr:
    """Σ coeff * var + const with integer coefficients."""

    terms: Tuple[Tuple[str, int], ...]  # ((var, coeff), ...) sorted by var
    const: int = 0

    @staticmethod
    def of(*terms: Tuple[str, int], const: int = 0) -> "LinExpr":
        merged: Dict[str, int] = {}
        for var, coeff in terms:
            if coeff:
                merged[var] = merged.get(var, 0) + coeff
        return LinExpr(tuple(sorted((v, c) for v, c in merged.items() if c)), const)

    @staticmethod
    def var(name: str, coeff: int = 1) -> "LinExpr":
        return LinExpr.of((name, coeff))

    @staticmethod
    def const_(value: int) -> "LinExpr":
        return LinExpr((), value)

    def __add__(self, other: "LinExpr") -> "LinExpr":
        return LinExpr.of(*self.terms, *other.terms, const=self.const + other.const)

    def scaled(self, k: int) -> "LinExpr":
        return LinExpr(tuple((v, c * k) for v, c in self.terms), self.const * k)

    @property
    def vars(self) -> frozenset:
        return frozenset(v for v, _ in self.terms)

    def coeff(self, var: str) -> int:
        for v, c in self.terms:
            if v == var:
                return c
        return 0

    def evaluate(self, env: Mapping[str, int]) -> int:
        return self.const + sum(c * env[v] for v, c in self.terms)


# --------------------------------------------------------------------------
# IR nodes
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TensorDecl:
    name: str
    shape: Tuple[int, ...]
    dtype_bytes: int = 4


@dataclasses.dataclass(frozen=True)
class Access:
    """A load or store of ``tensor[indices...]``."""

    tensor: str
    indices: Tuple[LinExpr, ...]
    is_store: bool = False

    @property
    def vars(self) -> frozenset:
        out: frozenset = frozenset()
        for ix in self.indices:
            out |= ix.vars
        return out

    def canonical(self, extents: Mapping[str, int]) -> Tuple:
        """Pattern key invariant to variable *names*: per dim, the sorted
        multiset of (coeff, extent) pairs + const. Two accesses with the same
        canonical key touch identical index sets over their loops."""
        dims = []
        for ix in self.indices:
            dims.append(
                (tuple(sorted((c, extents[v]) for v, c in ix.terms)), ix.const)
            )
        return (self.tensor, tuple(dims))


@dataclasses.dataclass(frozen=True)
class Compute:
    """A statement: op over loads producing a store.

    ``op`` ∈ {"fma", "add", "mul", "max", "exp", "rsqrt", "copy", "matmul_tile",
    "select"} — "matmul_tile" marks a statement the schedule maps onto the MXU
    (an (m,n,k) micro-tile contraction), everything else maps to vector units.
    """

    op: str
    output: Access
    inputs: Tuple[Access, ...]

    @property
    def accesses(self) -> Tuple[Access, ...]:
        return self.inputs + (self.output,)


Node = Union["Loop", Compute]


@dataclasses.dataclass(frozen=True)
class Loop:
    """A counted loop ``for var in range(extent)`` over ``body``.

    kind: "serial" | "parallel" | "vector" | "unroll" | "block".
    "block" marks the Pallas grid / DMA tile boundary: entering one iteration
    implies a DMA of the working tile HBM→VMEM (and store back for outputs).
    """

    var: str
    extent: int
    body: Tuple[Node, ...]
    kind: str = "serial"

    def walk_loops(self) -> Iterable["Loop"]:
        """Pre-order DFS over loop nodes (paper Alg. 1: PREORDER-DFS-FOR-LOOP)."""
        yield self
        for child in self.body:
            if isinstance(child, Loop):
                yield from child.walk_loops()


@dataclasses.dataclass(frozen=True)
class Program:
    tensors: Tuple[TensorDecl, ...]
    roots: Tuple[Loop, ...]
    name: str = "prog"

    def tensor(self, name: str) -> TensorDecl:
        for t in self.tensors:
            if t.name == name:
                return t
        raise KeyError(name)

    def walk_loops(self) -> Iterable[Loop]:
        for r in self.roots:
            yield from r.walk_loops()

    def extents(self) -> Dict[str, int]:
        return {lp.var: lp.extent for lp in self.walk_loops()}

    def total_compute_statements(self) -> int:
        """Σ over Compute leaves of the product of enclosing extents."""
        total = 0

        def rec(node: Node, mult: int) -> None:
            nonlocal total
            if isinstance(node, Loop):
                for ch in node.body:
                    rec(ch, mult * node.extent)
            else:
                total += mult

        for r in self.roots:
            rec(r, 1)
        return total


# --------------------------------------------------------------------------
# Footprint counting for linear forms over iteration boxes
# --------------------------------------------------------------------------


def distinct_values(pairs: Sequence[Tuple[int, int]]) -> int:
    """Number of distinct values of ``Σ c_j v_j`` with ``0 <= v_j < n_j``.

    Exact for regular tilings: processing strides in ascending order and
    tracking (count, span), a level either falls inside the current span
    (dense extension → contiguous image) or beyond it (pure product). Our
    schedule spaces only generate such decompositions; ``tests/`` verifies
    exactness against brute-force enumeration with hypothesis.
    """
    pairs = [(abs(c), n) for c, n in pairs if c != 0 and n > 1]
    if not pairs:
        return 1
    pairs.sort()
    count = 1
    span = 0  # max attainable value so far (min is 0)
    for c, n in pairs:
        if c <= span + 1:
            # dense extension: contiguous if the image was contiguous; the
            # min() caps the estimate at the product bound otherwise
            span = span + c * (n - 1)
            count = min(span + 1, count * n)
        else:
            count = count * n
            span = span + c * (n - 1)
    return count


def footprint_elements(
    access_patterns: Iterable[Tuple],  # canonical keys (see Access.canonical)
) -> int:
    """Union cardinality over canonicalised patterns of one tensor.

    Identical patterns were deduplicated by the caller; distinct patterns are
    summed (an upper bound on the union — exact when patterns touch disjoint
    regions, the common case in our spaces)."""
    total = 0
    for _, dims in access_patterns:
        n = 1
        for coeff_extents, _const in dims:
            n *= distinct_values([(c, e) for c, e in coeff_extents])
        total += n
    return total


def access_footprint(access: Access, extents: Mapping[str, int], live_vars) -> int:
    """Footprint (elements) of one access with ``live_vars`` ranging and all
    other vars fixed."""
    n = 1
    for ix in access.indices:
        pairs = [(c, extents[v]) for v, c in ix.terms if v in live_vars]
        n *= distinct_values(pairs)
    return n
