"""Tuna tuner — the public entry point tying Eq. (1) together:

    argmin_{t ∈ T_e}  c(f(g(e, t), a))

``tune(space, target)`` runs the ES search (Alg. 4) with the static cost
model as fitness; ``rank_space`` exhaustively scores a space (used by the
top-k experiments and by the kernel library's block-spec picker, whose spaces
are small). Results are memoised per (space signature, target) so model code
can call ``tuned_matmul_blocks`` at trace time for free.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import cost_model, es
from repro.core.spaces import MatmulSpace, Space
from repro.hw import get_target
from repro.hw.target import HardwareTarget


@dataclasses.dataclass
class TuneResult:
    config: Dict
    score: float
    evaluations: int
    wall_seconds: float
    history: List[float]
    default_score: float  # score of the space's centre config (no tuning)


def _score_config(space: Space, target: HardwareTarget, cfg: Dict,
                  coeffs: Optional[Dict[str, float]] = None) -> float:
    prog, meta = space.instantiate(cfg)
    return cost_model.evaluate(prog, target, meta, coeffs=coeffs)


def tune(
    space: Space,
    target: HardwareTarget,
    iterations: int = 12,
    population: int = 16,
    seed: int = 0,
    workers: int = 8,
) -> TuneResult:
    t0 = time.perf_counter()
    cache: Dict[Tuple, float] = {}

    def fitness(theta: np.ndarray) -> float:
        cfg = space.decode(theta)
        key = tuple(sorted(cfg.items()))
        if key not in cache:
            cache[key] = _score_config(space, target, cfg)
        return -cache[key]

    res = es.evolve(
        fitness,
        dim=space.dim,
        iterations=iterations,
        population=population,
        seed=seed,
        workers=workers,
    )
    best_cfg = space.decode(res.best_theta)
    best_score = _score_config(space, target, best_cfg)
    return TuneResult(
        config=best_cfg,
        score=best_score,
        evaluations=res.evaluations,
        wall_seconds=time.perf_counter() - t0,
        history=res.history,
        default_score=_score_config(space, target, space.default_config()),
    )


def rank_space(
    space: Space, target: HardwareTarget, limit: int = 4096,
    coeffs: Optional[Dict[str, float]] = None,
) -> List[Tuple[Dict, float]]:
    """Static exhaustive ranking (ascending score = predicted fastest first)."""
    scored = [
        (cfg, _score_config(space, target, cfg, coeffs))
        for cfg in space.enumerate(limit)
    ]
    scored.sort(key=lambda cs: cs[1])
    return scored


@functools.lru_cache(maxsize=256)
def tuned_matmul_blocks(
    M: int, N: int, K: int, dtype_bytes: int = 2, target_name: str = "tpu_v5e"
) -> Tuple[int, int, int]:
    """Statically tuned Pallas block sizes for a matmul — used by kernels/ops.

    Exhaustive over the (small) block space: this is what a production
    compilation service would run at model-compile time, on any host, with no
    TPU attached (the paper's cross-compilation requirement)."""
    target = get_target(target_name)
    space = MatmulSpace(M, N, K, dtype_bytes, target_kind="tpu")
    ranked = rank_space(space, target, limit=1024)
    best = ranked[0][0]
    return best["bm"], best["bn"], best["bk"]
