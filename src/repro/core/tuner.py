"""Tuna tuner — the public entry point tying Eq. (1) together:

    argmin_{t ∈ T_e}  c(f(g(e, t), a))

``tune(space, target)`` runs the ES search (Alg. 4) with the static cost
model as fitness; ``rank_space`` exhaustively scores a space (used by the
top-k experiments and by the kernel library's block-spec picker, whose spaces
are small). Results are memoised per (space signature, target) so model code
can call ``tuned_matmul_blocks`` at trace time for free.

Persistence: because scores are pure functions of (op signature, target,
cost-model version), both entry points consult the ``repro.tuna`` schedule
database before searching and write back on miss. ``db`` arguments accept a
``ScheduleDatabase``, a path, ``None`` (= the process default set via
``set_default_db`` / the ``REPRO_TUNA_DB`` env var), or ``False`` (bypass —
used by the orchestrator, which manages its own store). An immutable
serving snapshot (``repro.tuna.cache.ScheduleCache``, installed via
``set_default_cache`` / ``$REPRO_TUNA_CACHE``) is consulted before the DB
on every read — the lock-free hot path for serving processes.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import cost_model, es
from repro.core.cost_model import COST_MODEL_VERSION
from repro.core.spaces import MatmulSpace, Space
from repro.hw import get_target
from repro.hw.target import HardwareTarget

_UNSET = object()
_DEFAULT_DB = _UNSET  # _UNSET = fall back to $REPRO_TUNA_DB; None = off
_DEFAULT_CACHE = _UNSET  # _UNSET = fall back to $REPRO_TUNA_CACHE
_DEFAULT_BUNDLE = _UNSET  # _UNSET = fall back to $REPRO_TUNA_BUNDLE
_DEFAULT_LEARNED = _UNSET  # _UNSET = fall back to $REPRO_TUNA_LEARNED
_DEFAULT_CACHE_PATH: Optional[str] = None  # where the default snapshot was
#                                   installed from — what hot reload rechecks
_PATH_DBS: Dict[str, object] = {}  # abspath -> ScheduleDatabase (one load
#                                    per path per process, not per call)
_PATH_CACHES: Dict[str, object] = {}  # abspath -> ScheduleCache snapshot
_MEMO_CLEARERS: List = []  # block-spec lru cache_clear hooks (kernels/ops
#                            registers tuned_flash_blocks here — tuner can't
#                            import kernels, which pulls in jax)


def register_memo_clearer(fn) -> None:
    _MEMO_CLEARERS.append(fn)


def _clear_memos() -> None:
    tuned_matmul_blocks.cache_clear()
    for fn in _MEMO_CLEARERS:
        fn()


def _open_db(path):
    key = os.path.abspath(os.fspath(path))
    if key not in _PATH_DBS:
        from repro.tuna.db import ScheduleDatabase

        _PATH_DBS[key] = ScheduleDatabase(key)
    return _PATH_DBS[key]


def set_default_db(db) -> None:
    """Install the process-wide warm schedule DB (path or ScheduleDatabase).
    ``None`` switches the default OFF, including the ``$REPRO_TUNA_DB``
    fallback. Clears the block-spec memo caches so already-traced shapes
    re-resolve against the new store."""
    global _DEFAULT_DB
    if isinstance(db, (str, os.PathLike)):
        db = _open_db(db)
    _DEFAULT_DB = db
    _clear_memos()


def get_default_db():
    """The installed default DB, else one opened from ``$REPRO_TUNA_DB``."""
    global _DEFAULT_DB
    if _DEFAULT_DB is _UNSET:
        path = os.environ.get("REPRO_TUNA_DB")
        _DEFAULT_DB = _open_db(path) if path else None
    return _DEFAULT_DB


def resolve_db(db):
    """Coerce a ``db`` argument to a ScheduleDatabase or None: ``False`` →
    off, ``None`` → the process default, a path → the per-path cached
    instance (one log read per process), an instance → itself (a
    ``ScheduleCache`` instance acts as a read-only store)."""
    if db is False:
        return None
    if db is None:
        return get_default_db()
    if isinstance(db, (str, os.PathLike)):
        return _open_db(db)
    return db


def _writable(store) -> bool:
    """Write-back gate: ``ScheduleCache`` is an immutable snapshot, so
    results found by a live search are not persisted through it."""
    return store is not None and not getattr(store, "immutable", False)


def _open_cache(path):
    """Per-path snapshot instances, revalidated by the snapshot's *stored
    content digest* (a cheap header read — no record parsing): a snapshot
    is immutable once loaded, so a republished file must hand out a fresh
    instance. stat-based stamps (mtime+size) are not enough — a transport
    pull that preserves timestamps (rsync ``--times``, object-store
    metadata) with an equal-size payload would serve the stale instance
    forever. ``latest`` pointer files revalidate the same way: the pointer
    header carries the target's sha1, so repointing changes the stamp."""
    key = os.path.abspath(os.fspath(path))
    from repro.tuna.cache import ScheduleCache, read_snapshot_header

    stamp = read_snapshot_header(key).get("sha1")
    cached = _PATH_CACHES.get(key)
    if cached is None or stamp is None or cached[0] != stamp:
        _PATH_CACHES[key] = (stamp, ScheduleCache.load(key))
    return _PATH_CACHES[key][1]


def set_default_cache(cache) -> None:
    """Install the process-wide serving snapshot (path or ScheduleCache),
    consulted *before* the schedule DB on every read. ``None`` switches it
    OFF, including the ``$REPRO_TUNA_CACHE`` fallback. Clears the
    block-spec memo caches so already-traced shapes re-resolve. Installing
    a path remembers it, so ``refresh_default_cache`` can hot-swap when
    the snapshot is republished. A missing, corrupt, or stale (wrong
    ``COST_MODEL_VERSION``) snapshot raises — an explicit install must
    never silently serve nothing."""
    global _DEFAULT_CACHE, _DEFAULT_CACHE_PATH
    if isinstance(cache, (str, os.PathLike)):
        path = os.path.abspath(os.fspath(cache))
        cache = _open_cache(path)
        _DEFAULT_CACHE_PATH = path
    else:
        _DEFAULT_CACHE_PATH = None
    _DEFAULT_CACHE = cache
    _clear_memos()


def get_default_cache():
    """The installed snapshot, else one loaded from ``$REPRO_TUNA_CACHE``.
    An env-var path that does not exist yet (snapshot not built) resolves
    to OFF instead of failing every lookup — unlike ``set_default_cache``,
    where an explicit install of a missing file raises. A *stale* env
    snapshot (built under a different ``COST_MODEL_VERSION``) also
    resolves to OFF, but loudly: a ``StaleSnapshotWarning`` says why every
    lookup is about to pay a full search and how to rebuild. Either way
    the path is remembered so ``refresh_default_cache`` picks up the
    rebuilt snapshot without a restart."""
    global _DEFAULT_CACHE, _DEFAULT_CACHE_PATH
    if _DEFAULT_CACHE is _UNSET:
        path = os.environ.get("REPRO_TUNA_CACHE")
        if not path:
            _DEFAULT_CACHE = None
        else:
            from repro.tuna.cache import (StaleSnapshotError,
                                          StaleSnapshotWarning)

            _DEFAULT_CACHE_PATH = os.path.abspath(path)
            try:
                _DEFAULT_CACHE = _open_cache(path)
            except FileNotFoundError:
                _DEFAULT_CACHE = None  # not built yet; refresh may find it
                _clear_memos()
            except StaleSnapshotError as e:
                import warnings

                warnings.warn(f"$REPRO_TUNA_CACHE disabled: {e}",
                              StaleSnapshotWarning, stacklevel=2)
                _DEFAULT_CACHE = None
                # degrading to OFF changes what every block-spec lookup
                # resolves to — without this, shapes memoised while an
                # earlier (now-rejected) snapshot was installed keep
                # serving its block specs until process restart
                _clear_memos()
    return _DEFAULT_CACHE


def refresh_default_cache() -> bool:
    """Hot-reload the default serving snapshot if its content changed.

    Long-running serve processes call this between waves: it re-reads the
    snapshot header at the installed path (following a ``latest``
    pointer), compares the stored sha1 against the instance being served,
    and swaps in a fresh ``ScheduleCache`` — clearing the block-spec
    memos — when a republish landed. Returns True iff a swap happened
    (the new instance starts with zeroed hit/miss counters). While the
    new file is missing, torn, mid-publish, or stale, the current
    instance keeps serving — a failed poll never takes the cache away."""
    global _DEFAULT_CACHE
    cur = get_default_cache()  # resolves the env var on first use
    path = _DEFAULT_CACHE_PATH
    if path is None:
        return False
    try:
        new = _open_cache(path)
    except (OSError, ValueError):
        # missing/unreadable file (NFS blips included) or a stale/corrupt
        # snapshot (StaleSnapshotError is a ValueError): keep serving
        return False
    if new is cur:
        return False
    _DEFAULT_CACHE = new
    _clear_memos()
    return True


def set_default_bundle(bundle) -> None:
    """Install the process-wide golden kernel bundle
    (``repro.tuna.golden.KernelBundle``, or a path/`latest` pointer to
    one), consulted before the snapshot cache *and* the DB on every read —
    the blessed-release tier. ``None`` switches it OFF, including the
    ``$REPRO_TUNA_BUNDLE`` fallback. Clears the block-spec memo caches so
    already-traced shapes re-resolve against the release."""
    global _DEFAULT_BUNDLE
    if isinstance(bundle, (str, os.PathLike)):
        from repro.tuna.golden import KernelBundle

        bundle = KernelBundle.load(bundle)
    _DEFAULT_BUNDLE = bundle
    _clear_memos()


def get_default_bundle():
    """The installed kernel bundle, else one loaded from
    ``$REPRO_TUNA_BUNDLE``. Mirrors ``get_default_cache``'s env handling:
    a path that does not exist resolves to OFF; a stale bundle (different
    ``COST_MODEL_VERSION``) resolves to OFF with a ``StaleSnapshotWarning``
    — and both degrade paths clear the block-spec memos."""
    global _DEFAULT_BUNDLE
    if _DEFAULT_BUNDLE is _UNSET:
        path = os.environ.get("REPRO_TUNA_BUNDLE")
        if not path:
            _DEFAULT_BUNDLE = None
        else:
            from repro.tuna.cache import (StaleSnapshotError,
                                          StaleSnapshotWarning)
            from repro.tuna.golden import KernelBundle

            try:
                _DEFAULT_BUNDLE = KernelBundle.load(path)
            except FileNotFoundError:
                _DEFAULT_BUNDLE = None
                _clear_memos()
            except StaleSnapshotError as e:
                import warnings

                warnings.warn(f"$REPRO_TUNA_BUNDLE disabled: {e}",
                              StaleSnapshotWarning, stacklevel=2)
                _DEFAULT_BUNDLE = None
                _clear_memos()
    return _DEFAULT_BUNDLE


def set_default_learned(model) -> None:
    """Install the process-wide learned ranker
    (``repro.core.learned.LearnedRanker``, or a path/`latest` pointer to a
    saved artifact) used by ``rank_space``/``best_schedule`` to re-rank the
    statically-pruned top candidates. ``None`` switches it OFF, including
    the ``$REPRO_TUNA_LEARNED`` fallback. Clears the block-spec memo
    caches so already-traced shapes re-resolve under the hybrid version.
    An explicit install of a missing, corrupt, tampered, or stale (wrong
    ``COST_MODEL_VERSION``) artifact raises — never silently served."""
    global _DEFAULT_LEARNED
    if isinstance(model, (str, os.PathLike)):
        from repro.core.learned import load_ranker

        model = load_ranker(model)
    _DEFAULT_LEARNED = model
    _clear_memos()


def get_default_learned():
    """The installed learned ranker, else one loaded from
    ``$REPRO_TUNA_LEARNED``. Mirrors ``get_default_cache``'s env handling:
    a path that does not exist (model not trained yet) resolves to OFF; a
    stale artifact (different ``COST_MODEL_VERSION``) resolves to OFF with
    a ``StaleSnapshotWarning`` — and both degrade paths clear the
    block-spec memos, so shapes memoised under an earlier model never
    outlive its rejection."""
    global _DEFAULT_LEARNED
    if _DEFAULT_LEARNED is _UNSET:
        path = os.environ.get("REPRO_TUNA_LEARNED")
        if not path:
            _DEFAULT_LEARNED = None
        else:
            from repro.core.learned import load_ranker
            from repro.tuna.cache import (StaleSnapshotError,
                                          StaleSnapshotWarning)

            try:
                _DEFAULT_LEARNED = load_ranker(path)
            except FileNotFoundError:
                _DEFAULT_LEARNED = None  # not trained yet
                _clear_memos()
            except StaleSnapshotError as e:
                import warnings

                warnings.warn(f"$REPRO_TUNA_LEARNED disabled: {e}",
                              StaleSnapshotWarning, stacklevel=2)
                _DEFAULT_LEARNED = None
                _clear_memos()
    return _DEFAULT_LEARNED


def resolve_learned(learned):
    """Coerce a ``learned`` argument: ``False`` → off, ``None`` → the
    process default, a path → a loaded (and verified) artifact, an
    instance → itself."""
    if learned is False:
        return None
    if learned is None:
        return get_default_learned()
    if isinstance(learned, (str, os.PathLike)):
        from repro.core.learned import load_ranker

        return load_ranker(learned)
    return learned


def _lookup(op: str, target_name: str, version: str, db):
    """Read path shared by tune/best_schedule/block-spec pickers: golden
    kernel bundle first (the blessed release), then the snapshot cache
    (O(1), lock-free), then the schedule DB. Returns
    ``(record or None, "bundle"|"cache"|"db"|"")`` and never searches."""
    bundle = get_default_bundle()
    if bundle is not None:
        rec = bundle.best(op, target_name, version)
        if rec is not None:
            return rec, "bundle"
    cache = get_default_cache()
    if cache is not None:
        rec = cache.best(op, target_name, version)
        if rec is not None:
            return rec, "cache"
    store = resolve_db(db)
    if store is not None and store is not cache:
        rec = store.best(op, target_name, version)
        if rec is not None:
            return rec, "db"
    return None, ""


def lookup_best(op: str, target_name: str,
                version: str = COST_MODEL_VERSION, db=None):
    """Best stored record for a key — serving-cache first, then the DB
    (``db`` follows ``resolve_db`` semantics). None on a full miss."""
    return _lookup(op, target_name, version, db)[0]


def record_version(coeffs: Optional[Dict[str, float]] = None) -> str:
    """Cost-model version tag for a schedule record. Datasheet coefficients
    → plain ``cm1``. Custom (calibrated) coefficients are host-specific, so
    their scores are only comparable to records from the same fit — the
    coefficient fingerprint becomes part of the key, keeping merged stores
    from mixing incomparable score scales."""
    if coeffs is None:
        return COST_MODEL_VERSION
    blob = json.dumps(coeffs, sort_keys=True, default=float)
    fp = hashlib.sha1(blob.encode()).hexdigest()[:8]
    return f"{COST_MODEL_VERSION}-cal-{fp}"


@dataclasses.dataclass
class TuneResult:
    config: Dict
    score: float
    evaluations: int
    wall_seconds: float
    history: List[float]
    default_score: float  # score of the space's centre config (no tuning)
    from_db: bool = False  # True when served from the schedule database
    from_cache: bool = False  # True when the hit came from a ScheduleCache
    default_score_missing: bool = False  # True on warm hits whose stored
    #   record carries no default_score (e.g. written by rank_space with
    #   the centre config outside the enumeration limit): default_score is
    #   NaN then, and speedup math / JSON emitters must treat it as absent
    #   rather than serialize bare NaN (invalid JSON)


def _score_config(space: Space, target: HardwareTarget, cfg: Dict,
                  coeffs: Optional[Dict[str, float]] = None) -> float:
    prog, meta = space.instantiate(cfg)
    return cost_model.evaluate(prog, target, meta, coeffs=coeffs)


def tune(
    space: Space,
    target: HardwareTarget,
    iterations: int = 12,
    population: int = 16,
    seed: int = 0,
    workers: int = 8,
    db=None,
) -> TuneResult:
    """ES search (Alg. 4); warm-DB hits return with **zero** cost-model
    evaluations, misses are written back under strategy ``es``."""
    t0 = time.perf_counter()
    if db is not False:  # False = full bypass, snapshot cache included
        rec, source = _lookup(space.signature(), target.name,
                              COST_MODEL_VERSION, db)
        if rec is not None:
            # NaN when the stored record carries no default_score (e.g. it
            # was written by rank_space) — a warm hit spends zero
            # evaluations, so we won't recompute it here; the explicit
            # default_score_missing flag is what downstream speedup math
            # and JSON emitters key off (bare NaN is invalid JSON)
            has_default = "default_score" in rec.meta
            return TuneResult(
                config=dict(rec.config),
                score=rec.score,
                evaluations=0,
                wall_seconds=time.perf_counter() - t0,
                history=[],
                default_score=float(
                    rec.meta.get("default_score", float("nan"))),
                from_db=True,
                from_cache=source in ("cache", "bundle"),
                default_score_missing=not has_default,
            )

    store = resolve_db(db)  # resolved on the miss path only: a snapshot
    #                         hit must not pay a JSONL log load
    cache: Dict[Tuple, float] = {}

    def fitness(theta: np.ndarray) -> float:
        cfg = space.decode(theta)
        key = tuple(sorted(cfg.items()))
        if key not in cache:
            cache[key] = _score_config(space, target, cfg)
        return -cache[key]

    res = es.evolve(
        fitness,
        dim=space.dim,
        iterations=iterations,
        population=population,
        seed=seed,
        workers=workers,
    )
    best_cfg = space.decode(res.best_theta)
    best_score = _score_config(space, target, best_cfg)
    result = TuneResult(
        config=best_cfg,
        score=best_score,
        evaluations=res.evaluations,
        wall_seconds=time.perf_counter() - t0,
        history=res.history,
        default_score=_score_config(space, target, space.default_config()),
    )
    if _writable(store):
        from repro.tuna.db import ScheduleRecord, stamp_tuned_at

        store.add(ScheduleRecord(
            op=space.signature(),
            target=target.name,
            config=dict(best_cfg),
            score=best_score,
            evaluations=res.evaluations,
            meta=stamp_tuned_at(
                {"strategy": "es", "default_score": result.default_score}),
        ))
    return result


def rank_space(
    space: Space, target: HardwareTarget, limit: int = 4096,
    coeffs: Optional[Dict[str, float]] = None,
    db=False,
    learned=False,
    rerank_top: int = 32,
) -> List[Tuple[Dict, float]]:
    """Static exhaustive ranking (ascending score = predicted fastest first).

    Callers need the full ranking, which the DB does not store, so this is a
    *write-back* integration: when a store resolves, the winning record is
    appended under strategy ``exhaustive`` (``best_schedule`` is the
    read path). Calibrated-coefficient rankings are stored under a
    fingerprinted version (``cm1-cal-<hash>``, see ``record_version``) so
    they never collide with datasheet scores or other hosts' fits.

    ``learned`` (``resolve_learned`` semantics; default OFF) makes the
    ranking *hybrid*: static ``cm1`` scores and prunes the space, the
    learned ranker re-orders the statically-best ``rerank_top`` candidates
    — still zero hardware measurements. Hybrid write-backs go under the
    model's fingerprinted version (``<base>+lr<fp>``, strategy ``hybrid``)
    so they never collide with pure static records.
    """
    scored = [
        (cfg, _score_config(space, target, cfg, coeffs))
        for cfg in space.enumerate(limit)
    ]
    scored.sort(key=lambda cs: cs[1])
    model = resolve_learned(learned)
    if model is not None:
        scored = model.rerank(space, target, scored, top=rerank_top)
    store = resolve_db(db)
    if _writable(store) and scored:
        from repro.tuna.db import ScheduleRecord, stamp_tuned_at

        version = record_version(coeffs)
        meta = {"strategy": "exhaustive", "limit": limit}
        if model is not None:
            version = model.hybrid_version(version)
            meta["strategy"] = "hybrid"
            meta["rerank_top"] = rerank_top
        dflt = space.default_config()
        default_score = next((s for c, s in scored if c == dflt), None)
        if default_score is not None:  # centre config inside the limit
            meta["default_score"] = default_score
        meta = stamp_tuned_at(meta)
        store.add(ScheduleRecord(
            op=space.signature(),
            target=target.name,
            config=dict(scored[0][0]),
            score=scored[0][1],
            evaluations=len(scored),
            meta=meta,
            version=version,
        ))
    return scored


def best_schedule(
    space: Space, target: HardwareTarget, limit: int = 1024, db=None,
    coeffs: Optional[Dict[str, float]] = None,
    version: Optional[str] = None,
    learned=None,
    rerank_top: int = 32,
) -> Tuple[Dict, float]:
    """Best (config, score) for a space: bundle/snapshot-cache/DB hit →
    zero evaluations; miss → exhaustive rank + write back (to a writable
    store only). The kernel block-spec pickers sit on this.

    ``version`` pins the record version consulted (and nothing else is
    tried) — the passthrough that lets calibrated ``cm1-cal-<fp>`` writes
    be calibrated warm hits instead of silently re-ranking under plain
    ``cm1``. Without it the version is derived: ``record_version(coeffs)``,
    and when a learned ranker resolves (``learned``; default = the process
    default, see ``set_default_learned``) the hybrid lineage
    (``<base>+lr<fp>``) is consulted first with the static lineage as
    fallback — existing cm1 bundles/caches keep their warm hits."""
    model = resolve_learned(learned) if version is None else None
    if db is not False:
        if version is not None:
            versions = [version]
        else:
            base = record_version(coeffs)
            versions = ([model.hybrid_version(base), base]
                        if model is not None else [base])
        for v in versions:
            rec = lookup_best(space.signature(), target.name, version=v,
                              db=db)
            if rec is not None:
                return dict(rec.config), rec.score
    store = resolve_db(db)  # miss path only, like tune()
    ranked = rank_space(space, target, limit=limit, coeffs=coeffs,
                        db=store if _writable(store) else False,
                        learned=model if model is not None else False,
                        rerank_top=rerank_top)
    return ranked[0]


@functools.lru_cache(maxsize=256)
def tuned_matmul_blocks(
    M: int, N: int, K: int, dtype_bytes: int = 2, target_name: str = "tpu_v5e"
) -> Tuple[int, int, int]:
    """Statically tuned Pallas block sizes for a matmul — used by kernels/ops.

    Exhaustive over the (small) block space: this is what a production
    compilation service would run at model-compile time, on any host, with no
    TPU attached (the paper's cross-compilation requirement). Consults the
    default schedule DB first, so a warm store makes this a pure lookup."""
    target = get_target(target_name)
    space = MatmulSpace(M, N, K, dtype_bytes, target_kind="tpu")
    best, _ = best_schedule(space, target, limit=1024)
    return best["bm"], best["bn"], best["bk"]
