"""TIR → VISA: deterministic lowering to a virtual low-level ISA.

The paper's Algorithm 1 jointly parses high-level IR (loop structure) and
*generated low-level code* (exact instruction mix after register allocation,
vectorization, unrolling). On the TPU deployment target we cannot obtain real
Mosaic assembly without hardware, so the framework lowers the scheduled TIR
itself to a **virtual ISA** that models what the backend emits:

* VLIW TensorCore units: ``mxu.*`` (systolic matmul tiles), ``vpu.*``
  (8×128 vector ops), ``dma.*`` (async HBM↔VMEM copies with byte payloads),
  ``scalar.*`` (loop bookkeeping: init / update / compare+jump).
* For the CPU validation target the same lowering emits ``simd.*`` 256-bit
  ops (vfmadd/vmov analogues) — the paper's Intel model.

Crucially the lowering performs the code-gen transformations that make naive
IR-level instruction counting wrong (the paper's motivation for Alg. 1):

* **register allocation of accumulators** — an output invariant to a
  reduction loop is hoisted into a register: loads/stores leave the loop body;
* **vectorization** — a ``vector`` loop collapses into ⌈extent/lanes⌉ vector
  ops, with broadcast loads for invariant operands;
* **tensorization** — a ``tensor.m/n/k`` micro-nest collapses into MXU tile
  ops (⌈m/128⌉⌈n/128⌉⌈k/128⌉ instructions);
* **unrolling** — ``unroll`` loops are replicated inline (no backward jump).

The emitted stream is *flat*: labels, forward/backward jumps, and register
init/update instructions. Loop structure is NOT annotated — Algorithm 1 /
Algorithm 3 in ``instcount.py`` must genuinely recover it (backward-jump
detection + register init/update maps), as in the paper.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.tir import Access, Compute, Loop, Program, access_footprint
from repro.hw.target import HardwareTarget

# opcode used by Compute.op -> (tpu vpu opcode, cpu simd opcode)
_OP_MAP = {
    "fma": ("vpu.fma", "simd.fma"),
    "add": ("vpu.add", "simd.add"),
    "mul": ("vpu.mul", "simd.mul"),
    "max": ("vpu.max", "simd.max"),
    "exp": ("vpu.exp", "simd.exp"),
    "rsqrt": ("vpu.rsqrt", "simd.rsqrt"),
    "copy": ("vpu.add", "simd.add"),
    "select": ("vpu.select", "simd.max"),
}


@dataclasses.dataclass
class VInstr:
    opcode: str
    dest: Optional[str] = None
    srcs: Tuple[str, ...] = ()
    meta: Dict = dataclasses.field(default_factory=dict)

    def __repr__(self) -> str:  # compact, assembly-ish
        m = f" ;{self.meta}" if self.meta else ""
        return f"{self.opcode} {self.dest or '_'} <- {','.join(self.srcs)}{m}"


@dataclasses.dataclass
class VisaProgram:
    instrs: List[VInstr]

    def text(self) -> str:
        return "\n".join(map(repr, self.instrs))


class _Lowerer:
    def __init__(self, program: Program, target: HardwareTarget):
        self.program = program
        self.target = target
        self.extents = program.extents()
        self.is_tpu = target.kind == "tpu"
        self.out: List[VInstr] = []
        self._reg = 0
        self._label = 0
        self.lanes = target.vreg_shape[0] * target.vreg_shape[1]

    # -- helpers ------------------------------------------------------
    def reg(self, hint: str = "r") -> str:
        self._reg += 1
        return f"{hint}{self._reg}"

    def label(self) -> str:
        self._label += 1
        return f"LBB{self._label}"

    def emit(self, opcode, dest=None, srcs=(), **meta) -> VInstr:
        ins = VInstr(opcode, dest, tuple(srcs), meta)
        self.out.append(ins)
        return ins

    def _vop(self, op: str) -> str:
        pair = _OP_MAP[op]
        return pair[0] if self.is_tpu else pair[1]

    def _ldst(self, load: bool) -> str:
        if self.is_tpu:
            return "vpu.load" if load else "vpu.store"
        return "simd.load" if load else "simd.store"

    # -- main ---------------------------------------------------------
    def run(self) -> VisaProgram:
        for root in self.program.roots:
            self.lower_node(root)
        return VisaProgram(self.out)

    def lower_node(self, node) -> None:
        if isinstance(node, Compute):
            self.lower_compute(node, vector_var=None)
            return
        assert isinstance(node, Loop)
        kind = node.kind
        if kind.startswith("tensor."):
            self.lower_tensor_nest(node)
        elif kind == "vector":
            self.lower_vector_loop(node)
        elif kind == "unroll":
            for _ in range(node.extent):
                for ch in node.body:
                    self.lower_node(ch)
        elif kind == "block":
            self.lower_block_loop(node)
        else:  # serial / parallel
            self.lower_counted_loop(node)

    # -- counted loop with accumulator hoisting ------------------------
    def lower_counted_loop(self, node: Loop) -> None:
        prev_env = dict(getattr(self, "_acc_env", {}) or {})
        env = dict(prev_env)
        emitted: List[Tuple[Tuple, List[str]]] = []
        for key, n_regs in self._hoistable_accumulators(node):
            if key in env:
                continue  # already hoisted by an outer reduction loop
            regs = [self.reg("acc") for _ in range(n_regs)]
            for r in regs:
                self.emit(self._ldst(True), r, (key[0],), hoisted=True)
            env[key] = regs
            emitted.append((key, regs))

        ctr = self.reg("i")
        lbl = self.label()
        self.emit("scalar.addr", ctr, (), init=0)  # register init
        self.emit("label", lbl)
        self._acc_env = env
        for ch in node.body:
            self.lower_node(ch)
        self.emit("scalar.loop", ctr, (ctr,), update=1)  # register update
        self.emit(
            "scalar.jump", None, (ctr,), target=lbl, bound=node.extent, backward=True
        )

        for key, regs in emitted:
            for r in regs:
                self.emit(self._ldst(False), None, (r, key[0]), hoisted=True)
        self._acc_env = prev_env

    def _hoistable_accumulators(self, node: Loop):
        """Accumulators hoistable out of this loop: fma outputs invariant to
        the loop var, either as a direct child statement or through a single
        vector loop (one register per vector lane-group, as a real register
        allocator would keep)."""
        out = []
        for ch in node.body:
            if (
                isinstance(ch, Compute)
                and ch.op == "fma"
                and node.var not in ch.output.vars
            ):
                out.append(((ch.output.tensor, ch.output.indices), 1))
            elif (
                isinstance(ch, Loop)
                and ch.kind == "vector"
                and len(ch.body) == 1
                and isinstance(ch.body[0], Compute)
                and ch.body[0].op == "fma"
                and node.var not in ch.body[0].output.vars
            ):
                lanes = self.target.vreg_shape[1]
                n_regs = math.ceil(ch.extent / lanes)
                out.append(
                    ((ch.body[0].output.tensor, ch.body[0].output.indices), n_regs)
                )
        return out

    # -- vector (innermost) loop ---------------------------------------
    def lower_vector_loop(self, node: Loop) -> None:
        lanes = self.target.vreg_shape[1]  # lane dim only: 128 tpu / 8 cpu
        n_vec = math.ceil(node.extent / lanes)
        tail_waste = (n_vec * lanes - node.extent) / (n_vec * lanes)
        for ch in node.body:
            assert isinstance(ch, Compute), "vector loops must be innermost"
            for i in range(n_vec):
                self.lower_compute(
                    ch,
                    vector_var=node.var,
                    lane_waste=tail_waste if i == n_vec - 1 else 0.0,
                    vec_idx=i,
                )

    def lower_compute(
        self, c: Compute, vector_var, lane_waste: float = 0.0, vec_idx: int = 0
    ) -> None:
        acc_env = getattr(self, "_acc_env", {}) or {}

        def pick(regs: List[str]) -> str:
            return regs[vec_idx % len(regs)]

        in_regs = []
        for acc in c.inputs:
            key = (acc.tensor, acc.indices)
            if key in acc_env:
                in_regs.append(pick(acc_env[key]))
                continue
            r = self.reg("v")
            if vector_var is not None and vector_var not in acc.vars:
                op = "vpu.load" if self.is_tpu else "simd.broadcast"
            else:
                op = self._ldst(True)
            self.emit(op, r, (acc.tensor,), waste=lane_waste)
            in_regs.append(r)
        okey = (c.output.tensor, c.output.indices)
        if okey in acc_env:
            dest = pick(acc_env[okey])
            self.emit(self._vop(c.op), dest, tuple(in_regs) + (dest,), waste=lane_waste)
        else:
            dest = self.reg("v")
            if c.op == "fma":  # read-modify-write accumulate
                prev = self.reg("v")
                self.emit(self._ldst(True), prev, (c.output.tensor,), waste=lane_waste)
                self.emit(self._vop(c.op), dest, tuple(in_regs) + (prev,), waste=lane_waste)
            else:
                self.emit(self._vop(c.op), dest, tuple(in_regs), waste=lane_waste)
            self.emit(self._ldst(False), None, (dest, c.output.tensor), waste=lane_waste)

    # -- tensorized micro-nest -> MXU ----------------------------------
    def lower_tensor_nest(self, node: Loop) -> None:
        dims = {"m": 1, "n": 1, "k": 1}
        cur: object = node
        stmt = None
        while isinstance(cur, Loop) and cur.kind.startswith("tensor."):
            dims[cur.kind.split(".", 1)[1]] = cur.extent
            assert len(cur.body) == 1, "tensor nest must be a perfect nest"
            cur = cur.body[0]
        stmt = cur
        assert isinstance(stmt, Compute)
        if not self.is_tpu:
            # CPU: re-lower as serial m / serial k / vector n
            inner = Loop(
                var=f"{node.var}__n",
                extent=dims["n"],
                body=(stmt,),
                kind="vector",
            )
            kl = Loop(var=f"{node.var}__k", extent=dims["k"], body=(inner,), kind="serial")
            ml = Loop(var=f"{node.var}__m", extent=dims["m"], body=(kl,), kind="serial")
            self.lower_node(ml)
            return
        mxu_m, mxu_n = self.target.mxu_shape
        tiles = (
            math.ceil(dims["m"] / mxu_m)
            * math.ceil(dims["n"] / mxu_n)
            * math.ceil(dims["k"] / mxu_m)
        )
        util = (dims["m"] * dims["n"] * dims["k"]) / (
            tiles * mxu_m * mxu_n * mxu_m
        )
        for _ in range(tiles):
            self.emit(
                "mxu.matmul",
                self.reg("t"),
                (stmt.inputs[0].tensor, stmt.inputs[1].tensor),
                util=util,
                m=dims["m"],
                n=dims["n"],
                k=dims["k"],
            )

    # -- block (grid/DMA tile) loop ------------------------------------
    def lower_block_loop(self, node: Loop) -> None:
        """Pallas-grid / cache-tile boundary: one DMA per tensor per grid
        step. Tensors invariant to the block var stay resident in VMEM across
        iterations (Pallas revisiting semantics) — their DMAs are hoisted
        outside the loop, like register-allocated accumulators."""
        inner_vars = self._vars_below(node)
        tensors_in, tensors_out = self._tensors_below(node)
        dtype = {t.name: t.dtype_bytes for t in self.program.tensors}

        def fp_bytes(acc: Access, name: str) -> int:
            return access_footprint(acc, self.extents, inner_vars) * dtype[name]

        for name, acc in tensors_in.items():
            if node.var not in acc.vars:  # resident across grid steps
                self.emit("dma.load", self.reg("d"), (name,),
                          bytes=fp_bytes(acc, name), hoisted=True)

        ctr = self.reg("g")
        lbl = self.label()
        self.emit("scalar.addr", ctr, (), init=0)
        self.emit("label", lbl)
        for name, acc in tensors_in.items():
            if node.var in acc.vars:
                self.emit("dma.load", self.reg("d"), (name,),
                          bytes=fp_bytes(acc, name))
        for ch in node.body:
            self.lower_node(ch)
        for name, acc in tensors_out.items():
            if node.var in acc.vars:
                self.emit("dma.store", None, (name,), bytes=fp_bytes(acc, name))
        self.emit("scalar.loop", ctr, (ctr,), update=1)
        self.emit(
            "scalar.jump", None, (ctr,), target=lbl, bound=node.extent, backward=True
        )
        for name, acc in tensors_out.items():
            if node.var not in acc.vars:
                self.emit("dma.store", None, (name,),
                          bytes=fp_bytes(acc, name), hoisted=True)

    def _vars_below(self, node: Loop):
        vs = set()

        def rec(n):
            if isinstance(n, Loop):
                vs.add(n.var)
                for ch in n.body:
                    rec(ch)

        for ch in node.body:
            rec(ch)
        return frozenset(vs)

    def _tensors_below(self, node: Loop):
        ins: Dict[str, Access] = {}
        outs: Dict[str, Access] = {}

        def rec(n):
            if isinstance(n, Loop):
                for ch in n.body:
                    rec(ch)
            else:
                for a in n.inputs:
                    ins.setdefault(a.tensor, a)
                outs.setdefault(n.output.tensor, n.output)
                if n.op == "fma":  # accumulation also reads the output
                    ins.setdefault(n.output.tensor, n.output)

        for ch in node.body:
            rec(ch)
        return ins, outs


def lower_program(program: Program, target: HardwareTarget) -> VisaProgram:
    return _Lowerer(program, target).run()
