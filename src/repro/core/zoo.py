"""Model-zoo operator families registered with the declarative registry.

The paper's §V.B set (matmul / conv / depthwise / bmm, registered by
:mod:`repro.core.spaces`) only covers classic CNN-era workloads, but the
repo's ``models/`` already runs MoE dispatch, SSM scans, mLSTM recurrences
and GQA attention.  This module registers those hot loops as first-class
tunable ops so the (op family × target) matrix the tuner, learned ranker and
fleet sweep actually spans the model zoo:

  * ``moe_dispatch`` — the per-(batch, expert) token GEMM behind
    ``models/moe.py``'s dispatch: C tokens of width D against an expert FFN
    of width F, wrapped in a (B, E) parallel grid.
  * ``ssm_scan``     — ``models/ssm.py``'s chunked selective scan: per chunk,
    a state update H[n,d] += B[t,n]·X[t,d] and an output contraction
    Y[t,d] += C[t,n]·H[n,d], tiled over (chunk, b_d).
  * ``mlstm_chunk``  — ``models/xlstm.py``'s chunkwise mLSTM recurrence:
    per R-row chunk an (R×R) score GEMM then an (R×dh) output GEMM, tiled
    over (br, bh).
  * ``flash`` / ``flash_gqa`` — attention-variant spaces whose knobs are
    exactly ``kernels/flash_attention.py``'s ``block_q``/``block_k`` grid;
    ``flash`` keeps the historical single-head signature the block-spec
    picker and golden bundles already use, ``flash_gqa`` adds head-group and
    causal attributes.

Importing this module (or calling any registry API) makes the families
available; ``repro.core.spaces`` always registers first so the legacy
learned-ranker feature columns stay a stable prefix.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.cost_model import ScheduleMeta
from repro.core.op_registry import (
    DTYPE_BY_BYTES,
    AttrSpec,
    BundleSkip,
    BundleSpec,
    KnobFeature,
    OpDef,
    Preset,
    register,
)
from repro.core.spaces import (
    MATMUL_KNOB_FEATURES,
    _build_matmul,
    _divisors_pow2,
    _matmul_knobs,
    _wrap_parallel,
)
from repro.core.tir import Access, Compute, LinExpr, Loop, Program, TensorDecl

__all__ = [
    "MOE_DISPATCH_DEF",
    "SSM_SCAN_DEF",
    "MLSTM_CHUNK_DEF",
    "FLASH_DEF",
    "FLASH_GQA_DEF",
]

_STAGED = ("tpu", "gpu")  # kinds with an explicit fast-memory staging loop


# ---------------------------------------------------------------------------
# MoE token-dispatch GEMM
# ---------------------------------------------------------------------------


def _moe_matmul_attrs(attrs: Dict) -> Dict:
    return {"M": attrs["C"], "N": attrs["F"], "K": attrs["D"],
            "dtype_bytes": attrs["dtype_bytes"]}


def _moe_knobs(attrs: Dict, kind: str) -> Dict[str, List]:
    return _matmul_knobs(_moe_matmul_attrs(attrs), kind)


def _build_moe_dispatch(attrs: Dict, cfg: Dict,
                        kind: str) -> Tuple[Program, ScheduleMeta]:
    prog, meta = _build_matmul(_moe_matmul_attrs(attrs), cfg, kind)
    B, E = attrs["B"], attrs["E"]
    return _wrap_parallel(prog, meta, (("b", B), ("e", E)),
                          f"moe_dispatch_{B}x{E}x{attrs['C']}")


MOE_DISPATCH_DEF = register(OpDef(
    name="moe_dispatch",
    attrs=(AttrSpec("B"), AttrSpec("E"), AttrSpec("C"), AttrSpec("D"),
           AttrSpec("F"), AttrSpec("dtype_bytes", int, 4)),
    knob_fn=_moe_knobs,
    build_fn=_build_moe_dispatch,
    knob_features=MATMUL_KNOB_FEATURES,
    presets={
        "moe_dispatch": Preset(
            {"B": 2, "E": 8, "C": 128, "D": 256, "F": 512}, "cpu"),
    },
    doc="per-(batch, expert) token GEMM: Y[b,e,C,F] += X[b,e,C,D] @ W[b,e,D,F]",
))


# ---------------------------------------------------------------------------
# SSM chunked selective scan
# ---------------------------------------------------------------------------


def _ssm_knobs(attrs: Dict, kind: str) -> Dict[str, List]:
    knobs: Dict[str, List] = {
        "chunk": _divisors_pow2(attrs["S"], 8, 256),
        "b_d": _divisors_pow2(attrs["D"], 8, 512),
    }
    if kind in _STAGED:
        knobs["double_buffer"] = [False, True]
    return knobs


def _build_ssm_scan(attrs: Dict, cfg: Dict,
                    kind: str) -> Tuple[Program, ScheduleMeta]:
    S, D, N, db = attrs["S"], attrs["D"], attrs["N"], attrs["dtype_bytes"]
    chunk, b_d = cfg["chunk"], cfg["b_d"]
    X = TensorDecl("X", (S, D), db)
    Bm = TensorDecl("Bm", (S, N), db)
    Cm = TensorDecl("Cm", (S, N), db)
    Hs = TensorDecl("H", (N, D), db)
    Y = TensorDecl("Y", (S, D), db)
    row = LinExpr.of(("ci", chunk), ("tu", 1))
    col = LinExpr.of(("dt", b_d), ("dv", 1))
    # state update: H[n, d] += Bm[t, n] * X[t, d]
    upd = Compute(
        "fma",
        output=Access("H", (LinExpr.var("n"), col), is_store=True),
        inputs=(Access("Bm", (row, LinExpr.var("n"))),
                Access("X", (row, col))),
    )
    row_o = LinExpr.of(("ci", chunk), ("to", 1))
    col_o = LinExpr.of(("dt", b_d), ("dw", 1))
    # output contraction: Y[t, d] += Cm[t, n] * H[n, d]
    out = Compute(
        "fma",
        output=Access("Y", (row_o, col_o), is_store=True),
        inputs=(Access("Cm", (row_o, LinExpr.var("no"))),
                Access("H", (LinExpr.var("no"), col_o))),
    )
    upd_nest = Loop("tu", chunk, (Loop("n", N, (Loop(
        "dv", b_d, (upd,), "vector"),), "serial"),), "serial")
    out_nest = Loop("to", chunk, (Loop("no", N, (Loop(
        "dw", b_d, (out,), "vector"),), "serial"),), "serial")
    dt = Loop("dt", D // b_d, (upd_nest, out_nest), "serial")
    ci = Loop("ci", S // chunk, (dt,),
              "block" if kind in _STAGED else "serial")
    prog = Program((X, Bm, Cm, Hs, Y), (ci,),
                   name=f"ssm_scan_{S}x{D}x{N}")
    meta = ScheduleMeta(
        grid_size=(S // chunk) * (D // b_d),
        parallel_extent=D // b_d,  # the scan itself is serial over chunks
        vmem_tile_bytes=(chunk * b_d + 2 * chunk * N + N * b_d) * db,
        double_buffer=bool(cfg.get("double_buffer", False)),
    )
    return _wrap_parallel(prog, meta, (("b", attrs["B"]),),
                          f"ssm_scan_{attrs['B']}x{S}x{D}")


SSM_SCAN_DEF = register(OpDef(
    name="ssm_scan",
    attrs=(AttrSpec("B"), AttrSpec("S"), AttrSpec("D"), AttrSpec("N"),
           AttrSpec("dtype_bytes", int, 4)),
    knob_fn=_ssm_knobs,
    build_fn=_build_ssm_scan,
    knob_features=(
        KnobFeature("chunk", "log2"),
        KnobFeature("b_d", "log2"),
        KnobFeature("double_buffer", "flag"),
    ),
    presets={
        "ssm_scan": Preset({"B": 2, "S": 512, "D": 256, "N": 16}, "cpu"),
    },
    doc="chunked selective scan: H += B·X per chunk, Y += C·H",
))


# ---------------------------------------------------------------------------
# mLSTM chunkwise recurrence
# ---------------------------------------------------------------------------


def _mlstm_knobs(attrs: Dict, kind: str) -> Dict[str, List]:
    knobs: Dict[str, List] = {
        "br": _divisors_pow2(attrs["R"], 8, 128),
        "bh": _divisors_pow2(attrs["dh"], 8, 128),
    }
    if kind in _STAGED:
        knobs["double_buffer"] = [False, True]
    return knobs


def _build_mlstm_chunk(attrs: Dict, cfg: Dict,
                       kind: str) -> Tuple[Program, ScheduleMeta]:
    S, R, dh = attrs["S"], attrs["R"], attrs["dh"]
    db = attrs["dtype_bytes"]
    br, bh = cfg["br"], cfg["bh"]
    Q = TensorDecl("Q", (S, dh), db)
    K = TensorDecl("K", (S, dh), db)
    V = TensorDecl("V", (S, dh), db)
    Sc = TensorDecl("Sc", (S, R), 4)   # f32 score chunk
    O = TensorDecl("O", (S, dh), db)
    q_row = LinExpr.of(("ci", R), ("rt", br), ("tm", 1))
    # scores: Sc[q, r] += Q[q, :] · K[ci*R + r, :]
    score = Compute(
        "fma",
        output=Access("Sc", (q_row, LinExpr.var("tn")), is_store=True),
        inputs=(Access("Q", (q_row, LinExpr.var("tk"))),
                Access("K", (LinExpr.of(("ci", R), ("tn", 1)),
                             LinExpr.var("tk")))),
    )
    score_nest = Loop("tm", br, (Loop("tn", R, (Loop(
        "tk", dh, (score,), "tensor.k"),), "tensor.n"),), "tensor.m")
    o_row = LinExpr.of(("ci", R), ("rt", br), ("om", 1))
    o_col = LinExpr.of(("ht", bh), ("on", 1))
    # output: O[q, h] += Sc[q, r] * V[ci*R + r, h]
    outc = Compute(
        "fma",
        output=Access("O", (o_row, o_col), is_store=True),
        inputs=(Access("Sc", (o_row, LinExpr.var("ok"))),
                Access("V", (LinExpr.of(("ci", R), ("ok", 1)), o_col))),
    )
    out_nest = Loop("om", br, (Loop("on", bh, (Loop(
        "ok", R, (outc,), "tensor.k"),), "tensor.n"),), "tensor.m")
    ht = Loop("ht", dh // bh, (out_nest,), "serial")
    rt = Loop("rt", R // br, (score_nest, ht), "serial")
    ci = Loop("ci", S // R, (rt,),
              "block" if kind in _STAGED else "serial")
    prog = Program((Q, K, V, Sc, O), (ci,), name=f"mlstm_chunk_{S}x{R}x{dh}")
    meta = ScheduleMeta(
        grid_size=S // R,
        parallel_extent=1,  # the chunk recurrence is serial
        vmem_tile_bytes=(3 * R * dh) * db + R * R * 4,
        double_buffer=bool(cfg.get("double_buffer", False)),
    )
    return _wrap_parallel(prog, meta,
                          (("b", attrs["B"]), ("h", attrs["H"])),
                          f"mlstm_{attrs['B']}x{attrs['H']}x{S}")


MLSTM_CHUNK_DEF = register(OpDef(
    name="mlstm_chunk",
    attrs=(AttrSpec("B"), AttrSpec("H"), AttrSpec("S"), AttrSpec("R"),
           AttrSpec("dh"), AttrSpec("dtype_bytes", int, 4)),
    knob_fn=_mlstm_knobs,
    build_fn=_build_mlstm_chunk,
    knob_features=(
        KnobFeature("br", "log2"),
        KnobFeature("bh", "log2"),
        KnobFeature("double_buffer", "flag"),
    ),
    presets={
        "mlstm_chunk": Preset(
            {"B": 1, "H": 4, "S": 512, "R": 64, "dh": 64}, "cpu"),
    },
    doc="chunkwise mLSTM: per chunk an RxR score GEMM then an Rxdh out GEMM",
))


# ---------------------------------------------------------------------------
# Flash attention (single-head legacy signature) and GQA variant
# ---------------------------------------------------------------------------


def _flash_knobs(attrs: Dict, kind: str) -> Dict[str, List]:
    # exactly the kernels/flash_attention.py grid knobs — the block-spec
    # picker and golden bundles consume these keys verbatim
    s = attrs["s"]
    return {
        "block_q": _divisors_pow2(s, 128, 1024),
        "block_k": _divisors_pow2(s, 128, 1024),
    }


def _build_flash(attrs: Dict, cfg: Dict,
                 kind: str) -> Tuple[Program, ScheduleMeta]:
    s, d, db = attrs["s"], attrs["d"], attrs["dtype_bytes"]
    hq = attrs.get("hq", 1)
    bq, bk = cfg["block_q"], cfg["block_k"]
    # one head's online-softmax tile stream; heads only scale the grid
    Q = TensorDecl("Q", (s, d), db)
    K = TensorDecl("K", (s, d), db)
    V = TensorDecl("V", (s, d), db)
    P = TensorDecl("P", (s, bk), 4)    # f32 probability tile
    O = TensorDecl("O", (s, d), db)
    q_row = LinExpr.of(("qi", bq), ("tm", 1))
    score = Compute(
        "fma",
        output=Access("P", (q_row, LinExpr.var("tn")), is_store=True),
        inputs=(Access("Q", (q_row, LinExpr.var("tk"))),
                Access("K", (LinExpr.of(("ki", bk), ("tn", 1)),
                             LinExpr.var("tk")))),
    )
    score_nest = Loop("tm", bq, (Loop("tn", bk, (Loop(
        "tk", d, (score,), "tensor.k"),), "tensor.n"),), "tensor.m")
    e_row = LinExpr.of(("qi", bq), ("te", 1))
    expc = Compute(
        "exp",
        output=Access("P", (e_row, LinExpr.var("tj")), is_store=True),
        inputs=(Access("P", (e_row, LinExpr.var("tj"))),),
    )
    exp_nest = Loop("te", bq, (Loop("tj", bk, (expc,), "vector"),), "serial")
    o_row = LinExpr.of(("qi", bq), ("om", 1))
    outc = Compute(
        "fma",
        output=Access("O", (o_row, LinExpr.var("on")), is_store=True),
        inputs=(Access("P", (o_row, LinExpr.var("ok"))),
                Access("V", (LinExpr.of(("ki", bk), ("ok", 1)),
                             LinExpr.var("on")))),
    )
    out_nest = Loop("om", bq, (Loop("on", d, (Loop(
        "ok", bk, (outc,), "tensor.k"),), "tensor.n"),), "tensor.m")
    ki = Loop("ki", s // bk, (score_nest, exp_nest, out_nest),
              "block" if kind in _STAGED else "serial")
    qi = Loop("qi", s // bq, (ki,), "serial")
    prog = Program((Q, K, V, P, O), (qi,), name=f"flash_{hq}x{s}x{d}")
    # mirrors the kernels/ops.py VMEM estimate: q/o blocks + k/v blocks +
    # the m/l softmax carries and the probability tile
    vmem = (bq * d + 2 * bk * d + bq * d) * db + bq * (2 * 128 + bk) * 4
    meta = ScheduleMeta(
        grid_size=hq * (s // bq) * (s // bk),
        parallel_extent=hq * (s // bq),
        vmem_tile_bytes=vmem,
        double_buffer=False,
    )
    return prog, meta


def _flash_bundle(attrs: Dict, config: Dict) -> BundleSpec:
    dtype = DTYPE_BY_BYTES.get(attrs["dtype_bytes"])
    if dtype is None:
        raise BundleSkip("unsupported dtype_bytes")
    if not {"block_q", "block_k"} <= set(config):
        raise BundleSkip("no block_q/block_k in config")
    s, d = attrs["s"], attrs["d"]
    shape = (1, 1, s, d)   # canonical single-head, batch-1 layout
    return BundleSpec("flash", ((shape, dtype),) * 3,
                      {"causal": True, "scale": d ** -0.5})


FLASH_DEF = register(OpDef(
    name="flash",
    attrs=(AttrSpec("s"), AttrSpec("d"), AttrSpec("dtype_bytes", int, 2)),
    knob_fn=_flash_knobs,
    build_fn=_build_flash,
    bundle_fn=_flash_bundle,
    knob_features=(
        KnobFeature("block_q", "log2"),
        KnobFeature("block_k", "log2"),
    ),
    presets={
        "flash_1024": Preset({"s": 1024, "d": 64}, "tpu"),
    },
    doc="single-head flash attention block grid (legacy picker signature)",
))


def _gqa_bundle(attrs: Dict, config: Dict) -> BundleSpec:
    dtype = DTYPE_BY_BYTES.get(attrs["dtype_bytes"])
    if dtype is None:
        raise BundleSkip("unsupported dtype_bytes")
    if not {"block_q", "block_k"} <= set(config):
        raise BundleSkip("no block_q/block_k in config")
    s, d = attrs["s"], attrs["d"]
    hq, hkv = attrs["hq"], attrs["hkv"]
    if hq % hkv:
        raise BundleSkip("hq must be a multiple of hkv")
    q_aval = ((1, hq, s, d), dtype)
    kv_aval = ((1, hkv, s, d), dtype)
    return BundleSpec("flash", (q_aval, kv_aval, kv_aval),
                      {"causal": attrs["causal"], "scale": d ** -0.5})


def _gqa_build(attrs: Dict, cfg: Dict,
               kind: str) -> Tuple[Program, ScheduleMeta]:
    return _build_flash(attrs, cfg, kind)


FLASH_GQA_DEF = register(OpDef(
    name="flash_gqa",
    attrs=(AttrSpec("s"), AttrSpec("d"), AttrSpec("hq"), AttrSpec("hkv"),
           AttrSpec("causal", bool, True),
           AttrSpec("dtype_bytes", int, 2)),
    knob_fn=_flash_knobs,
    build_fn=_gqa_build,
    bundle_fn=_gqa_bundle,
    knob_features=(
        KnobFeature("block_q", "log2"),
        KnobFeature("block_k", "log2"),
    ),
    presets={
        "flash_gqa": Preset(
            {"s": 512, "d": 64, "hq": 8, "hkv": 2, "causal": True}, "tpu"),
    },
    doc="grouped-query flash attention: hq query heads over hkv kv heads",
))
