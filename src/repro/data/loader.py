"""Background-prefetching loader with straggler instrumentation.

A worker thread keeps ``depth`` batches ahead of the consumer; fetch latency
per step is recorded so the runtime straggler monitor (runtime/straggler.py)
can flag slow input shards. ``skip_to(step)`` supports bit-exact restart.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Dict, Optional

import numpy as np


class PrefetchLoader:
    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next_fetch = start_step
        self._stop = threading.Event()
        self.fetch_seconds: Dict[int, float] = {}
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        while not self._stop.is_set():
            step = self._next_fetch
            t0 = time.perf_counter()
            batch = self.source.batch(step)
            self.fetch_seconds[step] = time.perf_counter() - t0
            self._next_fetch = step + 1
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue

    def get(self, expected_step: Optional[int] = None):
        step, batch = self._q.get()
        if expected_step is not None and step != expected_step:
            # restart path: drain until aligned (source is random-access)
            while step < expected_step:
                step, batch = self._q.get()
            if step != expected_step:
                batch = self.source.batch(expected_step)
                step = expected_step
        return step, batch

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
