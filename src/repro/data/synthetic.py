"""Deterministic synthetic token pipeline.

Every (stream, step, position) maps to a token via a splittable counter-based
hash (philox-style mix) — so any worker can materialise any batch slice
without coordination, restarts are bit-exact, and data-parallel shards are
provably disjoint (tests/test_data.py). A memmap-backed file source with the
same interface covers the "real corpus" path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


def _mix(x: np.ndarray) -> np.ndarray:
    # 64-bit splitmix
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = x
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & np.uint64(
        0xFFFFFFFFFFFFFFFF
    )
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & np.uint64(
        0xFFFFFFFFFFFFFFFF
    )
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticTokens:
    """Indexable stream: batch(step) -> {tokens, labels} int32 arrays."""

    def __init__(self, cfg: SyntheticConfig, shard: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rows = self.shard * self.local_batch + np.arange(self.local_batch)
        # unique counter per (seed, step, row, position)
        pos = np.arange(c.seq_len + 1, dtype=np.uint64)
        ctr = (
            np.uint64(c.seed) * np.uint64(0x100000000)
            + np.uint64(step) * np.uint64(c.global_batch * (c.seq_len + 1))
            + rows[:, None].astype(np.uint64) * np.uint64(c.seq_len + 1)
            + pos[None, :]
        )
        toks = (_mix(ctr) % np.uint64(c.vocab)).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class MemmapTokens:
    """File-backed token stream (.bin of int32), same interface."""

    def __init__(self, path: str, seq_len: int, global_batch: int,
                 shard: int = 0, num_shards: int = 1):
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = global_batch // num_shards
        self.tokens_per_step = global_batch * (seq_len + 1)
        self.n_steps = len(self.data) // self.tokens_per_step

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        step = step % max(1, self.n_steps)
        base = step * self.tokens_per_step + self.shard * self.local_batch * (
            self.seq_len + 1
        )
        flat = np.asarray(
            self.data[base: base + self.local_batch * (self.seq_len + 1)]
        ).reshape(self.local_batch, self.seq_len + 1)
        return {"tokens": flat[:, :-1], "labels": flat[:, 1:]}
