"""Hardware target descriptions used by the Tuna static cost models.

Each target is a plain dataclass of published datasheet constants — no
measurement is required to instantiate one (the paper's cross-compilation
constraint).
"""
from repro.hw.target import HardwareTarget, FunctionalUnit
from repro.hw.tpu_v5e import TPU_V5E
from repro.hw.cpu_avx2 import CPU_AVX2
from repro.hw.gpu_a100 import GPU_A100

TARGETS = {t.name: t for t in (TPU_V5E, CPU_AVX2, GPU_A100)}


def get_target(name: str) -> HardwareTarget:
    try:
        return TARGETS[name]
    except KeyError:
        raise KeyError(f"unknown hardware target {name!r}; have {sorted(TARGETS)}")
