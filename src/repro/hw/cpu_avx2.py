"""Generic AVX2-class CPU target (validation target — we can measure on it).

Mirrors the paper's Intel CPU cost model: vfmadd/vmov SIMD counts, L1 cache
locality, OoO ILP with issue-width structural hazards. Latencies follow
published Skylake-class tables (Agner Fog):

  * 256-bit FMA: latency 4, two FMA ports => inverse throughput 0.5 (we use
    integer cycles: two `simd.fma` units of issue width 1 each is modelled as
    one unit with issue_width 2).
  * L1D 32 KiB, 64 B lines.
"""
from repro.hw.target import FunctionalUnit, HardwareTarget

_CLOCK = 3.0e9

CPU_AVX2 = HardwareTarget(
    name="cpu_avx2",
    kind="cpu",
    vreg_shape=(1, 8),  # one ymm register = 8 f32 lanes
    mxu_shape=(1, 8),
    num_cores=1,  # per-core model; thread-level parallelism handled above
    units=(
        FunctionalUnit("fma", issue_width=2),    # ports 0+1
        FunctionalUnit("load", issue_width=2),   # ports 2+3
        FunctionalUnit("store", issue_width=1),  # port 4
        FunctionalUnit("alu", issue_width=2),
        FunctionalUnit("scalar", issue_width=2),
    ),
    instruction_table={
        "simd.fma": ("fma", 4, 1),
        "simd.add": ("fma", 4, 1),
        "simd.mul": ("fma", 4, 1),
        "simd.max": ("alu", 1, 1),
        "simd.exp": ("fma", 20, 8),   # polynomial expansion estimate
        "simd.rsqrt": ("fma", 4, 1),
        "simd.load": ("load", 5, 1),   # L1 hit latency
        "simd.store": ("store", 4, 1),
        "simd.broadcast": ("load", 5, 1),
        "scalar.addr": ("scalar", 1, 1),
        "scalar.loop": ("scalar", 1, 1),
        "scalar.jump": ("scalar", 1, 1),
    },
    issue_width=4,
    fast_mem_bytes=32 * 1024,  # L1D
    fast_mem_line=64,
    hbm_bandwidth=25e9,  # single-core sustainable DRAM stream
    clock_hz=_CLOCK,
    peak_flops_bf16=2 * 8 * 2 * _CLOCK,  # 2 FMA ports x 8 lanes x 2 flops
    peak_flops_f32=2 * 8 * 2 * _CLOCK,
    ici_bandwidth=0.0,
)
