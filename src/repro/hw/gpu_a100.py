"""NVIDIA A100 (SXM4 40GB) target description (static third target).

Datasheet / microbenchmark constants (public; NVIDIA Ampere whitepaper and
Jia et al.'s "Dissecting the NVIDIA Ampere GPU" latency tables):

  * 108 SMs at 1.41 GHz boost; 4 warp schedulers per SM (one warp
    instruction each per cycle).
  * Warp = 32 lanes; 64 FP32 FMA lanes per SM => 2 FFMA warp
    instructions/cycle; dependent-issue latency 4 cycles.
  * 16 SFUs per SM => one MUFU (exp/rsqrt) warp instruction every 2 cycles.
  * Combined 192 KiB L1/shared per SM, up to 164 KiB usable shared memory
    carveout; 128 B cache lines/sectors pairs.
  * HBM2e: 1555 GB/s; ~400-cycle DRAM round trip. ``dma.*`` models
    ``cp.async`` staging HBM -> shared memory, the Ampere analogue of the
    Pallas HBM->VMEM block copy: per-SM share of stream bandwidth is
    1555e9 / 1.41e9 / 108 ~= 10.2 B/cycle, so a 128 B line retires every
    ~13 cycles.
  * Tensor cores: 312 TFLOP/s bf16 dense; 19.5 TFLOP/s FP32 (non-TC).
  * NVLink 3: 25 GB/s per link per direction (12 links per GPU).

The cost model treats one SM as the core (``num_cores=108``): schedules earn
their parallel speedup through ``parallel_extent`` across SMs, matching how
the CUDA grid maps blocks to SMs. Lowering uses the generic ``simd.*`` path
(a warp is a 32-lane vector unit) plus ``dma.*`` for block-staging loops.
"""
from repro.hw.target import FunctionalUnit, HardwareTarget

_CLOCK = 1.41e9

_LINE_BYTES = 128  # L2 sector pair / smem staging granule
_HBM_BPC_PER_SM = 1555e9 / _CLOCK / 108  # ~10.2 bytes/cycle/SM
_DMA_LINE_CYCLES = max(1, round(_LINE_BYTES / _HBM_BPC_PER_SM))  # ~13

GPU_A100 = HardwareTarget(
    name="gpu_a100",
    kind="gpu",
    vreg_shape=(1, 32),  # one warp = 32 lanes
    mxu_shape=(1, 32),
    num_cores=108,  # SMs; grid blocks spread across them
    units=(
        FunctionalUnit("fma", issue_width=2),    # 64 FP32 lanes / 32
        FunctionalUnit("alu", issue_width=2),    # 64 INT32 lanes / 32
        FunctionalUnit("sfu", issue_width=1),    # 16 SFUs -> 1/2 warp-instr
        FunctionalUnit("lsu", issue_width=2),    # LD/ST + L1 128 B/cycle
        FunctionalUnit("dma", issue_width=2),    # cp.async pipe depth
        FunctionalUnit("scalar", issue_width=4),  # 4 warp schedulers
    ),
    # opcode -> (unit, latency, inverse throughput), cycles at 1.41 GHz
    instruction_table={
        "simd.fma": ("fma", 4, 1),
        "simd.add": ("fma", 4, 1),
        "simd.mul": ("fma", 4, 1),
        "simd.max": ("alu", 4, 1),
        "simd.exp": ("sfu", 10, 2),
        "simd.rsqrt": ("sfu", 10, 2),
        "simd.load": ("lsu", 28, 1),   # smem/L1-hit latency
        "simd.store": ("lsu", 28, 1),
        "simd.broadcast": ("lsu", 25, 1),  # smem broadcast / uniform load
        # cp.async block staging: HBM round trip + per-line stream rate
        "dma.load": ("dma", 400, _DMA_LINE_CYCLES),
        "dma.store": ("dma", 400, _DMA_LINE_CYCLES),
        "scalar.addr": ("scalar", 1, 1),
        "scalar.loop": ("scalar", 1, 1),
        "scalar.jump": ("scalar", 1, 1),
    },
    issue_width=4,  # one instruction per scheduler per cycle
    fast_mem_bytes=164 * 1024,  # max shared-memory carveout per SM
    fast_mem_line=_LINE_BYTES,
    hbm_bandwidth=1555e9,
    clock_hz=_CLOCK,
    peak_flops_bf16=312e12,  # dense tensor-core bf16
    peak_flops_f32=19.5e12,
    ici_bandwidth=25e9,  # NVLink 3, per link per direction
)

# chip-level constants for roofline reporting
HBM_BYTES = 40 * 1024**3
NVLINKS = 12
