"""Hardware target description.

A ``HardwareTarget`` carries everything the Tuna cost model needs:

* functional units (name, issue width) — structural hazards for the ILP
  scheduler (paper §III-A.3: "number of different processing unit");
* per-opcode latency/throughput tables (paper: "hardware instruction latency");
* memory hierarchy parameters (cache/VMEM capacity for the Alg. 2 locality
  model, bandwidths for the roofline terms);
* SIMD geometry (vector width / MXU shape) for instruction-count estimation
  and alignment penalties.

All values are published datasheet numbers; nothing here is measured on a
device.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Tuple


@dataclasses.dataclass(frozen=True)
class FunctionalUnit:
    name: str
    issue_width: int = 1  # ops accepted per cycle (structural hazard limit)


@dataclasses.dataclass(frozen=True)
class HardwareTarget:
    name: str
    kind: str  # "tpu" | "cpu" | "gpu"

    # --- compute geometry ---
    # (sublanes, lanes) of a vector register / tile; MXU systolic dims for tpu
    vreg_shape: Tuple[int, int]
    mxu_shape: Tuple[int, int]  # (128,128) on TPU; (1, simd_width) on CPU
    num_cores: int  # TensorCores per chip / physical cores per socket

    # --- functional units & instruction tables ---
    units: Tuple[FunctionalUnit, ...]
    # opcode -> (unit_name, latency_cycles, inverse_throughput_cycles)
    instruction_table: Mapping[str, Tuple[str, int, int]]
    issue_width: int  # total instructions issued per cycle across units

    # --- memory hierarchy ---
    fast_mem_bytes: int  # L1 for CPU, VMEM for TPU (Alg. 2 cache capacity S)
    fast_mem_line: int  # cache line / minimum DMA granule, bytes
    hbm_bandwidth: float  # bytes / second (main memory for CPU)
    clock_hz: float

    # --- roofline constants (chip level) ---
    peak_flops_bf16: float  # FLOP/s
    peak_flops_f32: float
    ici_bandwidth: float = 0.0  # bytes/s per link (TPU); 0 for CPU

    # convenience -----------------------------------------------------------
    def latency(self, opcode: str) -> int:
        return self.instruction_table[opcode][1]

    def unit_of(self, opcode: str) -> str:
        return self.instruction_table[opcode][0]

    def inv_throughput(self, opcode: str) -> int:
        return self.instruction_table[opcode][2]

    @property
    def bytes_per_cycle_hbm(self) -> float:
        return self.hbm_bandwidth / self.clock_hz
