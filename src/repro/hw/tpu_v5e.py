"""TPU v5e target description (the deployment target of this framework).

Datasheet constants (public):
  * 197 TFLOP/s bf16, 394 TOPS int8 per chip
  * 819 GB/s HBM bandwidth, 16 GiB HBM
  * 1 TensorCore per chip, MXU 128x128 systolic array
  * VREG tile 8x128 (sublanes x lanes)
  * ~50 GB/s per ICI link
  * VMEM ~128 MiB aggregate scratch is NOT architectural; we use the
    per-core software-visible VMEM working budget of 16 MiB that Pallas
    kernels tile against (configurable at cost-model call sites).

The VISA (virtual TPU ISA) opcodes modelled here reflect the units a real
TensorCore schedules: the MXU (systolic matmul), the VPU (8x128 vector ALU),
two DMA queues (HBM<->VMEM), and the scalar core that drives them (VLIW).
Latencies are in core clock cycles at 940 MHz, derived from first principles:

  * ``mxu.matmul`` processes a 128x128x128 tile; the systolic array retires
    128 MACs/lane/cycle => a full tile has inverse throughput 128 cycles and
    pipeline latency ~2x128.
  * ``vpu.*`` ops operate on one 8x128 VREG per cycle.
  * ``dma.*`` latency models the HBM round-trip (~500 cycles) with
    per-VREG-line inverse throughput of VREG bytes / (HBM B/s / clock).
"""
from repro.hw.target import FunctionalUnit, HardwareTarget

_CLOCK = 0.94e9

# bytes moved per dma.line op: one 8x128 f32 VREG tile = 4096 B
_VREG_BYTES = 8 * 128 * 4
_HBM_BPC = 819e9 / _CLOCK  # ~871 bytes/cycle
_DMA_LINE_CYCLES = max(1, round(_VREG_BYTES / _HBM_BPC))  # ~5

TPU_V5E = HardwareTarget(
    name="tpu_v5e",
    kind="tpu",
    vreg_shape=(8, 128),
    mxu_shape=(128, 128),
    num_cores=1,  # one TensorCore per v5e chip
    units=(
        FunctionalUnit("mxu", issue_width=1),
        FunctionalUnit("vpu", issue_width=2),
        FunctionalUnit("dma", issue_width=2),  # two DMA queues
        FunctionalUnit("scalar", issue_width=1),
    ),
    # opcode -> (unit, latency, inverse throughput)
    instruction_table={
        # one 128x128x128 bf16 tile-matmul. 197 TFLOP/s at 940 MHz is
        # ~209.6 kFLOP/cycle (4 MXUs); a 4.19-MFLOP tile retires in ~20
        # cycles; pipeline (fill+drain) latency ~140.
        "mxu.matmul": ("mxu", 140, 20),
        # VPU ops: one 8x128 VREG per cycle, short pipeline
        "vpu.fma": ("vpu", 4, 1),
        "vpu.add": ("vpu", 2, 1),
        "vpu.mul": ("vpu", 3, 1),
        "vpu.max": ("vpu", 2, 1),
        "vpu.exp": ("vpu", 8, 2),
        "vpu.rsqrt": ("vpu", 8, 2),
        "vpu.load": ("vpu", 3, 1),   # VMEM -> VREG
        "vpu.store": ("vpu", 3, 1),  # VREG -> VMEM
        "vpu.select": ("vpu", 2, 1),
        "vpu.iota": ("vpu", 2, 1),
        # async DMA: start costs issue slot; wait blocks on completion
        "dma.load": ("dma", 500, _DMA_LINE_CYCLES),   # HBM -> VMEM line
        "dma.store": ("dma", 500, _DMA_LINE_CYCLES),  # VMEM -> HBM line
        # scalar core bookkeeping
        "scalar.addr": ("scalar", 1, 1),
        "scalar.loop": ("scalar", 1, 1),
        "scalar.jump": ("scalar", 1, 1),
    },
    issue_width=4,  # VLIW bundle: scalar + vpu + mxu/dma slots
    fast_mem_bytes=16 * 1024 * 1024,  # VMEM working budget for one kernel
    fast_mem_line=_VREG_BYTES,
    hbm_bandwidth=819e9,
    clock_hz=_CLOCK,
    peak_flops_bf16=197e12,
    peak_flops_f32=49.25e12,
    ici_bandwidth=50e9,  # per link
)

# chip-count-level constants used by roofline reporting
HBM_BYTES = 16 * 1024**3
ICI_LINKS = 4  # 2D torus on v5e: 4 links/chip
