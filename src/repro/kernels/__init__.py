"""Pallas TPU kernels for the compute hot-spots Tuna schedules.

``matmul.py`` / ``flash_attention.py`` hold the ``pl.pallas_call`` kernels
(explicit BlockSpec VMEM tiling, MXU-aligned); ``ops.py`` the jit wrappers
that consult the static tuner for block sizes; ``ref.py`` the pure-jnp
oracles every kernel is allclose-tested against (interpret mode on CPU).
"""
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.matmul import matmul_pallas
