"""Flash-attention (forward) Pallas TPU kernel, GQA-aware, causal-capable.

Online-softmax blocked attention (Dao et al.) re-tiled for TPU: VMEM-resident
running (m, l, acc) scratch revisited across KV grid steps; KV is the
innermost "arbitrary" grid dimension; with ``causal=True`` fully-masked KV
blocks are skipped via ``pl.when`` (no MXU work issued for blocks strictly
above the diagonal).

Block sizes (block_q, block_k) are Tuna-tunable; ``ops.attention`` asks the
static tuner for them per shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params

_NEG_INF = -1e30

# Pallas trace counter (see kernels/matmul.py TRACE_COUNT): flat when the
# call was served by an AOT kernel-bundle executable instead of tracing.
TRACE_COUNT = 0


def _flash_kernel(
    q_ref,  # [1, block_q, d]
    k_ref,  # [1, block_k, d]
    v_ref,  # [1, block_k, d]
    o_ref,  # [1, block_q, d]
    m_ref,  # [block_q, 128] scratch (lane-replicated running max)
    l_ref,  # [block_q, 128] scratch
    acc_ref,  # [block_q, d] scratch
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    nk: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [block_q, block_k]
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            k_pos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_prev = m_ref[:, :1]  # [block_q, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # [block_q, block_k]
        corr = jnp.exp(m_prev - m_new)  # [block_q, 1]
        l_new = corr * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0],
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    if causal:
        # skip KV blocks strictly above the causal diagonal
        pl.when(qi * block_q + block_q - 1 >= ki * block_k)(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # [B, Hq, S, D]
    k: jax.Array,  # [B, Hkv, S, D]
    v: jax.Array,  # [B, Hkv, S, D]
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    global TRACE_COUNT
    TRACE_COUNT += 1
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)

    # fold (batch, q-head) into one "parallel" grid axis h:
    #   batch = h // hq, q-head = h % hq, kv row = batch*hkv + q-head//group
    qf = q.reshape(b * hq, s, d)
    kf = k.reshape(b * hkv, s, d)
    vf = v.reshape(b * hkv, s, d)
    grid = (b * hq, s // block_q, s // block_k)

    def kv_map(h, i, kk):
        return ((h // hq) * hkv + (h % hq) // group, kk, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale,
            causal=causal,
            block_q=block_q,
            block_k=block_k,
            nk=grid[2],
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, i, kk: (h, i, 0)),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, i, kk: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, s, d)
