"""Blocked matmul Pallas TPU kernel with Tuna-tunable BlockSpec tiling.

Grid (M/bm, N/bn, K/bk); K is the innermost ("arbitrary") grid dimension so
the f32 VMEM accumulator is revisited across K steps and written back once —
the schedule Tuna's TPU cost model scores (MatmulSpace in core/spaces.py maps
1:1 onto these BlockSpecs; the DMA-per-grid-step and resident-accumulator
semantics mirrored there are exactly what ``pl.pallas_call`` does here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params

# Pallas trace counter: bumped every time matmul_pallas builds the kernel
# (eager interpret run or inside a jit trace). An AOT-deserialized
# executable from a kernel bundle never re-enters this function, so
# "cold start pays zero Pallas compilations" is assertable as TRACE_COUNT
# staying flat — see kernels.ops.pallas_trace_counts.
TRACE_COUNT = 0


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """C[M,N] = A[M,K] @ B[K,N] (f32 accumulation, output in x.dtype)."""
    global TRACE_COUNT
    TRACE_COUNT += 1
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{n},{k}) not divisible by blocks ({bm},{bn},{bk})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, y)
