"""Jit-friendly kernel entry points with Tuna-tuned schedules.

``matmul`` / ``attention`` dispatch between the Pallas TPU kernels and the
jnp reference paths:

* on a TPU backend → Pallas with Tuna-statically-tuned block sizes;
* on CPU (this container, and any cross-compiling host) → the jnp oracle,
  unless ``force_pallas=True`` (interpret mode, used by tests).

Tuning happens at trace time via ``core.tuner`` — pure static analysis, no
device execution, memoised per shape (the paper's compilation-service flow).
Both block-spec pickers consult the golden kernel bundle first
(``use_kernel_bundle(path)`` or ``$REPRO_TUNA_BUNDLE``), then the serving
snapshot cache (``use_schedule_cache(path)`` or ``$REPRO_TUNA_CACHE``), and
then the warm ``repro.tuna`` schedule DB (``use_schedule_db(path)`` or
``$REPRO_TUNA_DB``): on a warm store, trace time pays a dict lookup, not a
search.

A loaded kernel bundle serves more than block specs: a Pallas-path call on
*concrete* arrays whose (kernel, shapes, dtype, semantic knobs) match a
bundled AOT executable skips trace+lower+compile entirely and runs the
deserialized executable — zero Pallas compilations at serve cold-start
(``pallas_trace_counts`` is the witness; ``benchmarks/cold_start.py``
measures it). Calls under an outer ``jit`` see tracers and fall through to
the ordinary trace path — an AOT executable cannot be inlined into someone
else's trace.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import op_registry, tuner
from repro.core.tuner import rank_space, tuned_matmul_blocks
from repro.hw import get_target
from repro.kernels import ref
from repro.kernels import flash_attention as _flash_mod
from repro.kernels import matmul as _matmul_mod
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.matmul import matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_schedule_db(path) -> None:
    """Point the kernel block-spec pickers at a warm schedule database."""
    tuner.set_default_db(path)  # clears all registered block-spec memos


def use_schedule_cache(path) -> None:
    """Serve block-spec picks from an immutable snapshot (``python -m
    repro.tuna snapshot``) — consulted before the DB, O(1) and lock-free."""
    tuner.set_default_cache(path)  # clears all registered block-spec memos


def refresh_schedule_cache() -> bool:
    """Hot-swap the installed snapshot if it was republished (revalidated
    by the snapshot's content digest, not file stat). Clears the block-spec
    memos on swap so already-traced shapes re-resolve; True iff swapped."""
    return tuner.refresh_default_cache()


def use_kernel_bundle(bundle) -> None:
    """Install a golden kernel bundle (``python -m repro.tuna golden
    --bundle``): a path (or ``latest`` pointer), a loaded
    ``repro.tuna.golden.KernelBundle``, or ``None`` to switch OFF. The
    bundle becomes the first schedule-lookup tier (before snapshot cache
    and DB), and Pallas-path calls on concrete arrays matching a bundled
    executable run ahead-of-time compiled code — no trace, no compile."""
    tuner.set_default_bundle(bundle)  # clears all block-spec memos


def get_kernel_bundle():
    """The installed ``KernelBundle`` (or None) — resolved through
    ``core.tuner`` so there is exactly one process-wide bundle."""
    return tuner.get_default_bundle()


def pallas_trace_counts() -> Dict[str, int]:
    """How many times each Pallas kernel family has been traced/built in
    this process — the zero-compile acceptance witness for bundled serving
    (an AOT executable served from the bundle never re-enters the kernel
    builders, so these stay flat)."""
    return {"matmul": _matmul_mod.TRACE_COUNT,
            "flash": _flash_mod.TRACE_COUNT}


def reset_pallas_trace_counts() -> None:
    _matmul_mod.TRACE_COUNT = 0
    _flash_mod.TRACE_COUNT = 0


def _bundle_executable(kernel: str, args, params: Optional[Dict] = None):
    """The installed bundle's AOT executable for this concrete call, or
    None. Tracers (an outer jit's abstract values) always miss: a
    serialized executable is a leaf computation, callable only on real
    arrays from op-by-op dispatch."""
    bundle = tuner.get_default_bundle()
    if bundle is None:
        return None
    if any(isinstance(a, jax.core.Tracer) for a in args):
        return None
    return bundle.executable(kernel, args, params)


@functools.lru_cache(maxsize=256)
def tuned_flash_blocks(
    s: int, d: int, dtype_bytes: int = 2, target_name: str = "tpu_v5e"
) -> Tuple[int, int]:
    """Static block_q/block_k choice for flash attention: score the induced
    (q·kᵀ then p·v) tile working set over the registry's ``flash`` space
    (whose knobs are exactly this kernel's grid)."""
    target = get_target(target_name)
    db = tuner.get_default_db()
    space = op_registry.make_space(
        "flash", {"s": s, "d": d, "dtype_bytes": dtype_bytes}, target.kind)
    sig = space.signature()
    rec = tuner.lookup_best(sig, target.name)  # snapshot cache, then DB
    if rec is not None:
        return rec.config["block_q"], rec.config["block_k"]
    best = (None, float("inf"))
    evals = 0
    for cfg in space.enumerate(None):
        bq, bk_ = cfg["block_q"], cfg["block_k"]
        evals += 1
        # tile working set: q, k, v, acc + softmax stats, double-buffered
        vmem = (bq * d + 2 * bk_ * d + bq * d) * dtype_bytes + bq * (
            2 * 128 + bk_
        ) * 4
        if 2 * vmem > target.fast_mem_bytes:
            continue
        # per-step MXU work: bq×bk×d + bq×d×bk
        tiles = (bq // 128 or 1) * (bk_ // 128 or 1) * max(1, d // 128)
        dma = (bq * d + 2 * bk_ * d) * dtype_bytes
        t = 2 * tiles * 20 / target.clock_hz + dma / target.hbm_bandwidth
        # prefer larger tiles (fewer grid steps / revisits) on ties
        steps = (s // bq) * (s // bk_)
        score = t * steps
        if score < best[1]:
            best = ((bq, bk_), score)
    blocks = best[0] or (min(512, s), min(512, s))
    if tuner._writable(db) and best[0] is not None:
        from repro.tuna.db import ScheduleRecord

        db.add(ScheduleRecord(
            op=sig, target=target.name,
            config={"block_q": blocks[0], "block_k": blocks[1]},
            score=best[1],
            evaluations=evals,
            meta={"strategy": "flash_grid"},
        ))
    return blocks


# set_default_db must invalidate this memo too (it lives here, not in
# core.tuner, because importing kernels pulls in jax)
tuner.register_memo_clearer(tuned_flash_blocks.cache_clear)


def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    blocks: Optional[Tuple[int, int, int]] = None,
    force_pallas: bool = False,
) -> jax.Array:
    """Tuna-tuned blocked matmul."""
    m, k = x.shape
    _, n = y.shape
    use_pallas = _on_tpu() or force_pallas
    if not use_pallas:
        return ref.matmul(x, y)
    if blocks is None:
        fn = _bundle_executable("matmul", (x, y))
        if fn is not None:
            return fn(x, y)
        blocks = tuned_matmul_blocks(m, n, k, x.dtype.itemsize)
    bm, bn, bk = blocks
    return matmul_pallas(
        x, y, bm=bm, bn=bn, bk=bk, interpret=not _on_tpu()
    )


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    blocks: Optional[Tuple[int, int]] = None,
    force_pallas: bool = False,
) -> jax.Array:
    """Tuna-tuned flash attention (falls back to the oracle off-TPU)."""
    use_pallas = _on_tpu() or force_pallas
    if not use_pallas:
        return ref.attention(q, k, v, causal=causal, scale=scale)
    s, d = q.shape[-2], q.shape[-1]
    if blocks is None:
        fn = _bundle_executable(
            "flash", (q, k, v),
            {"causal": causal,
             "scale": scale if scale is not None else d ** -0.5})
        if fn is not None:
            return fn(q, k, v)
        blocks = tuned_flash_blocks(s, d, q.dtype.itemsize)
    bq, bk = blocks
    return flash_attention_pallas(
        q, k, v, causal=causal, scale=scale, block_q=bq, block_k=bk,
        interpret=not _on_tpu(),
    )
