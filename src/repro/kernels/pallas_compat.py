"""Version shims for ``jax.experimental.pallas`` across jax releases."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams
_COMPILER_PARAMS_CLS = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None))


def compiler_params(**kwargs):
    """Build the TPU compiler-params struct for ``pl.pallas_call``."""
    if _COMPILER_PARAMS_CLS is None:
        raise ImportError(
            "this jax exposes neither pallas tpu CompilerParams nor "
            "TPUCompilerParams; cannot build TPU kernel compiler params")
    return _COMPILER_PARAMS_CLS(**kwargs)
