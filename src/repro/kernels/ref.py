"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose against
these across shape/dtype sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """C = A @ B with f32 accumulation."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def attention(
    q: jax.Array,  # [B, Hq, S, D]
    k: jax.Array,  # [B, Hkv, S, D]
    v: jax.Array,  # [B, Hkv, S, D]
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Full-softmax GQA attention oracle."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, s, d)
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, s, d).astype(q.dtype)
