"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be imported/run before any other jax usage — the first two lines pin
512 placeholder host devices so ``jax.make_mesh`` can build the production
meshes (jax locks the device count at first init).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k --multi-pod
Outputs one JSON record per cell under experiments/dryrun/.
"""
import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import functools  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ARCH_IDS, get_config  # noqa: E402
from repro.core.hlo_features import loop_scaled_collectives, parse_collectives  # noqa: E402
from repro.launch import mesh as mesh_mod  # noqa: E402
from repro.launch import specs as S  # noqa: E402
from repro.launch import steps as steps_mod  # noqa: E402
from repro.models.model import Model  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.parallel import context as pctx  # noqa: E402
from repro.parallel import sharding as sh  # noqa: E402


def _flops_bytes(cost):
    # jax < 0.5 wraps cost_analysis() in a one-element list
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost.get("flops", 0.0), cost.get("bytes accessed", 0.0)


def pick_accum_steps(batch: int, seq: int, mesh, target_tokens: int = 4096) -> int:
    """Gradient-accumulation microbatching: bound per-device microbatch
    tokens so scan-boundary activations fit HBM (EXPERIMENTS §Dry-run)."""
    dp = mesh_mod.axis_size(mesh, mesh_mod.dp_axes(mesh))
    accum = 1
    while (
        (batch // accum) * seq // dp > target_tokens
        and batch % (accum * 2) == 0
        and (batch // (accum * 2)) % dp == 0
    ):
        accum *= 2
    return accum


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    mesh=None,
    cfg=None,
    verbose: bool = True,
    variant: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Lower + compile one cell; returns the §Dry-run record.

    ``variant`` overrides distribution knobs for the §Perf hillclimb loop:
      accum_steps, grad_compression ("int8"), sp_seq (bool),
      state_dtype ("float32"|"bfloat16"|"int8"), remat (bool).
    """
    variant = variant or {}
    cfg = cfg or get_config(arch)
    cfg_over = {k: variant[k] for k in ("attn_chunk", "ssm_chunk",
                                        "mlstm_chunk", "remat_stack")
                if k in variant}
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    mesh = mesh if mesh is not None else mesh_mod.make_production_mesh(
        multi_pod=multi_pod
    )
    record: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": dict(zip(mesh.axis_names, (int(mesh.shape[a]) for a in
                                           mesh.axis_names))),
        "n_devices": int(mesh.size),
    }
    ok, why = S.shape_applicable(cfg, shape_name)
    if not ok:
        record["status"] = "skipped"
        record["why"] = why
        return record

    spec = S.SHAPES[shape_name]
    kind, seq, batch = spec["kind"], spec["seq"], spec["batch"]
    model = Model(cfg)
    t0 = time.perf_counter()

    # SP for the token-parallel kinds (train/prefill); decode runs S=1
    pctx.install(
        mesh_mod.dp_axes(mesh),
        tp_axis="model",
        tp_size=int(mesh.shape["model"]),
        sp_seq=variant.get("sp_seq", kind in ("train", "prefill")),
        mesh=mesh if variant.get("mixer_shard_map", False) else None,
        moe_pin=variant.get("moe_pin", False),
    )
    with mesh:
        params_s = S.abstract_params(model)
        p_shard = sh.params_sharding(params_s, mesh, cfg=cfg)
        if kind == "train":
            state_dtype = variant.get(
                "state_dtype", S.recommended_state_dtype(cfg)
            )
            record["opt_state_dtype"] = state_dtype
            opt_cfg = adamw.AdamWConfig(state_dtype=state_dtype)
            opt_s = jax.eval_shape(
                functools.partial(adamw.init_state, opt_cfg), params_s
            )
            o_shard = sh.opt_state_sharding(opt_s, params_s, mesh, cfg=cfg)
            batch_s = S.batch_specs(cfg, batch, seq)
            b_shard = sh.batch_sharding(batch_s, mesh)
            accum = variant.get("accum_steps",
                                pick_accum_steps(batch, seq, mesh))
            record["accum_steps"] = accum
            record["variant"] = {k: v for k, v in variant.items()}
            import jax.numpy as _jnp
            gd = variant.get("grad_dtype")
            step = steps_mod.make_train_step(
                model, opt_cfg, accum_steps=accum, grad_shardings=p_shard,
                grad_compression=variant.get("grad_compression"),
                grad_dtype=getattr(_jnp, gd) if gd else None,
            )
            metrics_sh = None  # let XLA place scalars
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, metrics_sh),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_s, opt_s, batch_s)
        elif kind == "prefill":
            batch_s = S.infer_batch_specs(cfg, batch, seq)
            b_shard = sh.batch_sharding(batch_s, mesh)
            cap = seq + (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
            step = steps_mod.make_prefill_step(model, cap=cap)
            cache_s = jax.eval_shape(step, params_s, batch_s)[0]
            c_shard = sh.cache_sharding(cache_s, mesh)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, b_shard),
                out_shardings=(c_shard, None, None),
            )
            lowered = jitted.lower(params_s, batch_s)
        else:  # decode
            cache_s = S.abstract_cache(model, batch, seq)
            c_shard = sh.cache_sharding(cache_s, mesh)
            dspec = S.decode_specs(cfg, batch, seq)
            tok_shard = sh.batch_sharding({"tokens": dspec["tokens"]}, mesh)[
                "tokens"
            ]
            step = steps_mod.make_serve_step(model)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, tok_shard, sh.replicated(mesh)),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_s, cache_s, dspec["tokens"],
                                   dspec["pos"])

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)  # while bodies counted ONCE (diagnostic)
    scaled = loop_scaled_collectives(hlo)  # trip-count corrected (§Roofline)
    flops, acc_bytes = _flops_bytes(cost)

    record.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        hlo_flops=flops,
        hlo_bytes=acc_bytes,
        collective_counts=coll.counts,
        collective_operand_bytes=coll.operand_bytes,
        collective_link_bytes=coll.link_bytes,
        collective_operand_bytes_scaled=scaled.operand_bytes,
        collective_link_bytes_scaled=scaled.link_bytes,
        collective_counts_scaled=scaled.counts,
        mem=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            code_bytes=mem.generated_code_size_in_bytes,
        ),
    )
    if verbose:
        print(
            f"[{arch} × {shape_name} × {record['mesh']}] compile ok "
            f"({t_lower:.1f}s lower / {t_compile:.1f}s compile)\n"
            f"  memory/device: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
            f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
            f"alias={mem.alias_size_in_bytes/2**30:.2f}GiB\n"
            f"  HLO flops={flops:.3e} bytes={acc_bytes:.3e} "
            f"collective_operand={coll.total_operand_bytes:.3e}B "
            f"counts={ {k: v for k, v in coll.counts.items() if v} }"
        )
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--schedule-db", default=None,
                    help="warm repro.tuna schedule DB (JSONL) for trace-time "
                         "kernel block-spec picks; only consulted when "
                         "kernels lower for TPU (host-forced CPU compiles "
                         "take the jnp reference path)")
    args = ap.parse_args()

    if args.schedule_db:
        from repro.kernels.ops import use_schedule_db

        use_schedule_db(args.schedule_db)
        if jax.default_backend() != "tpu":
            print("[tuna] note: --schedule-db installed, but this dry run "
                  "compiles on the CPU backend where kernels use the "
                  "reference path; block-spec picks are not exercised")
    os.makedirs(args.out, exist_ok=True)
    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = list(S.SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape_name}_{'multipod' if mp else 'pod'}"
                try:
                    rec = run_cell(arch, shape_name, multi_pod=mp)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {
                        "arch": arch,
                        "shape": shape_name,
                        "multi_pod": mp,
                        "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                    }
                    failures.append(tag)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=2, default=float)
    if failures:
        print(f"FAILED cells: {failures}")
        raise SystemExit(1)
    print("all requested cells passed")


if __name__ == "__main__":
    main()
