"""Continuous-batching serve engine: a slot state machine.

The wave scheduler (``launch/serve.py``) prefills ``slots`` requests
together and decodes them in lockstep — a finished request parks its slot
idle until the slowest request in the wave drains, and short waves are
padded with zero-prompts that burn full decode FLOPs per step. This module
replaces that with per-slot scheduling (design notes: README "Serving"):

* every slot carries its own position — ``Model.decode_step`` takes a
  ``[B]`` pos vector, so rows at different sequence depths share one
  decode launch;
* an **admission queue** holds waiting requests (earliest deadline first,
  FIFO among equal deadlines) and refills a slot the moment it frees
  (EOS, ``max_new``, or deadline) — prefill runs on a batch of one and its
  KV/state cache is scattered into the live cache at the free slot index;
* free slots keep decoding (the batch shape is static) but their rows are
  masked out of every report: ``wasted_slot_steps`` counts exactly those
  slot-steps, which is the quantity continuous batching drives down.

Schedule-snapshot hot reload polls at *admission* boundaries (the moment a
new request enters the engine) instead of wave boundaries, so a fleet
republish lands mid-traffic without waiting for a full wave to drain.

Per-request measurement: TTFT (submit -> first token) and end-to-end
latency, aggregated to p50/p95/p99 by ``latency_summary``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    eos_id: Optional[int] = None      # finish early when emitted
    deadline_s: Optional[float] = None  # wall budget from submission
    # measurement (filled by the engines; relative to serve() start)
    t_submit: float = 0.0
    t_first: Optional[float] = None   # TTFT instant
    t_done: Optional[float] = None
    truncated: bool = False           # deadline fired before max_new/EOS

    def finished(self) -> bool:
        return self.t_done is not None

    def wants_more(self) -> bool:
        return len(self.out) < self.max_new and not self.truncated and (
            self.eos_id is None or self.eos_id not in self.out)


def latency_summary(values: List[float]) -> Dict[str, float]:
    """p50/p95/p99 (+ mean) over per-request seconds."""
    if not values:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0}
    a = np.asarray(values, np.float64)
    return {"p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean())}


def request_stats(requests: List[Request]) -> Dict:
    """Per-request rows + aggregated TTFT / e2e latency percentiles."""
    rows, ttfts, lats = [], [], []
    for r in requests:
        ttft = None if r.t_first is None else r.t_first - r.t_submit
        lat = None if r.t_done is None else r.t_done - r.t_submit
        if ttft is not None:
            ttfts.append(ttft)
        if lat is not None:
            lats.append(lat)
        rows.append({"rid": r.rid, "prompt_len": len(r.prompt),
                     "max_new": r.max_new, "tokens": len(r.out),
                     "ttft_s": ttft, "latency_s": lat,
                     "truncated": r.truncated})
    return {"requests": rows, "ttft_s": latency_summary(ttfts),
            "latency_s": latency_summary(lats)}


def greedy_decode_reference(model, params, prompt: List[int], max_new: int,
                            cap: int, eos_id: Optional[int] = None) -> List[int]:
    """One-request-at-a-time greedy decode (scalar-pos path) — the oracle
    the schedulers must match token-for-token."""
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    cache, pos, last_logits = model.prefill(params, batch, cap)
    tok = int(jnp.argmax(last_logits[0, 0]))
    out = [tok]
    for t in range(max_new - 1):
        if eos_id is not None and out[-1] == eos_id:
            break
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([out[-1]], jnp.int32), pos + t)
        out.append(int(jnp.argmax(logits[0])))
    if eos_id is not None and eos_id in out:
        out = out[: out.index(eos_id) + 1]
    return out


class _Slot:
    __slots__ = ("req", "deadline")

    def __init__(self):
        self.req: Optional[Request] = None
        self.deadline: Optional[float] = None  # absolute perf_counter time

    @property
    def free(self) -> bool:
        return self.req is None


class ContinuousEngine:
    """Slot state machine over a live decode cache of width ``slots``.

    Invariants (see README "Serving"):
      * a FREE slot's cache content is garbage — refill overwrites the
        whole slot slice (every cache leaf, along the batch axis) at
        prefill-scatter time, so nothing leaks between tenants;
      * ``pos[i]`` is the write index of slot i's *next* token; free slots
        pin pos=0 and tok=0 (their writes land in a slice that refill
        replaces, and the per-slot mask keeps them out of live rows);
      * a request holds its slot from admission until EOS / ``max_new`` /
        deadline, then the slot frees on the same engine step.
    """

    def __init__(self, model, params, slots: int, cap: int, refresh=None):
        self.model = model
        self.params = params
        self.slots = slots
        self.cap = cap
        self.refresh = refresh
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, cap))
        self._decode = jax.jit(model.decode_step)
        # scatter one request's prefilled cache into the live cache at slot
        # index i: every leaf is [G, B, ...] (batch axis 1), so one
        # dynamic_update_slice per leaf replaces the whole slot slice
        self._insert = jax.jit(lambda live, one, i: jax.tree.map(
            lambda a, b: jax.lax.dynamic_update_slice_in_dim(
                a, b.astype(a.dtype), i, axis=1), live, one))
        self.cache = model.init_cache(slots, cap)
        self.pos = np.zeros(slots, np.int32)   # next write index per slot
        self.tok = np.zeros(slots, np.int32)   # last emitted token per slot
        self._slots = [_Slot() for _ in range(slots)]
        # stats
        self.engine_steps = 0        # decode launches
        self.slot_steps = 0          # slot-steps doing live work
        self.wasted_slot_steps = 0   # slot-steps on free slots
        self.prefills = 0
        self.cache_reloads = 0
        self.deadline_truncations = 0
        self._admitted = 0

    # ---------------------------------------------------------------- admit
    def _admit(self, slot_i: int, req: Request) -> None:
        prompt = np.asarray(req.prompt, np.int32)[None]
        cache_1, pos_1, last_logits = self._prefill(
            self.params, {"tokens": jnp.asarray(prompt)})
        self.prefills += 1
        tok0 = int(jnp.argmax(last_logits[0, 0]))
        self.cache = self._insert(self.cache, cache_1, slot_i)
        slot = self._slots[slot_i]
        slot.req = req
        slot.deadline = (None if req.deadline_s is None
                         else req.t_submit + req.deadline_s)
        self.pos[slot_i] = int(pos_1)
        self.tok[slot_i] = tok0
        req.out.append(tok0)
        req.t_first = time.perf_counter() - self._t0
        self._admitted += 1
        self._maybe_finish(slot_i)

    def _maybe_finish(self, slot_i: int) -> None:
        slot = self._slots[slot_i]
        req = slot.req
        now = time.perf_counter() - self._t0
        if slot.deadline is not None and now >= slot.deadline and req.wants_more():
            req.truncated = True
            self.deadline_truncations += 1
        if not req.wants_more():
            req.t_done = now
            slot.req = None
            slot.deadline = None
            self.pos[slot_i] = 0
            self.tok[slot_i] = 0

    # ----------------------------------------------------------------- run
    def run(self, requests: List[Request]) -> None:
        """Serve ``requests`` to completion. Admission order is earliest
        deadline first (stable for equal/absent deadlines)."""
        self._t0 = time.perf_counter()
        queue = sorted(
            requests,
            key=lambda r: (r.deadline_s if r.deadline_s is not None
                           else float("inf")),
        )
        queue.reverse()  # pop() from the tail = earliest deadline
        while queue or any(not s.free for s in self._slots):
            # refill every free slot; the snapshot poll rides the admission
            # boundary (not the very first batch — that snapshot was just
            # loaded at startup)
            admitting = queue and any(s.free for s in self._slots)
            if admitting and self.refresh is not None and self._admitted:
                if self.refresh():
                    self.cache_reloads += 1
            for i, s in enumerate(self._slots):
                if s.free and queue:
                    self._admit(i, queue.pop())
            live = [i for i, s in enumerate(self._slots) if not s.free]
            if not live:
                continue
            logits, self.cache = self._decode(
                self.params, self.cache,
                jnp.asarray(self.tok), jnp.asarray(self.pos))
            self.engine_steps += 1
            self.slot_steps += len(live)
            self.wasted_slot_steps += self.slots - len(live)
            nxt = np.asarray(jnp.argmax(logits, axis=-1))  # one host sync
            for i in live:
                req = self._slots[i].req
                req.out.append(int(nxt[i]))
                self.tok[i] = int(nxt[i])
                self.pos[i] += 1
                self._maybe_finish(i)

    def stats(self) -> Dict:
        return {"engine_steps": self.engine_steps,
                "slot_steps": self.slot_steps,
                "wasted_slot_steps": self.wasted_slot_steps,
                "prefills": self.prefills,
                "cache_reloads": self.cache_reloads,
                "deadline_truncations": self.deadline_truncations}
