"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def _axis_kwargs(n_axes: int) -> dict:
    # jax < 0.5 has no jax.sharding.AxisType (meshes default to Auto there)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small ones, e.g. (2,4) on 8 host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_kwargs(len(axes)))


def dp_axes(mesh) -> tuple:
    """Data-parallel mesh axes (gradient-reduction domain)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
