"""Batched serving driver (wave scheduling).

Requests are served in waves of ``slots``: each wave is prefilled *batched*
(the prefill path the dry-run lowers at 32k), then decoded in lockstep with
``serve_step`` — one token per engine step for every slot. The cache pytree
and shardings are identical to the dry-run's decode cells, so the engine is
the production step under a scheduler. (Per-slot continuous refill needs
per-slot position vectors — noted as an extension in DESIGN.md.)

CPU-scale demo:  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
    --reduced --requests 6 --slots 2 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)


class ServeEngine:
    def __init__(self, model: Model, params, slots: int, cap: int):
        self.model = model
        self.params = params
        self.slots = slots
        self.cap = cap
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, cap))
        self._decode = jax.jit(model.decode_step)
        self.engine_steps = 0

    def run_wave(self, wave: List[Request]) -> None:
        assert len({len(r.prompt) for r in wave}) == 1, "wave = equal prompts"
        n = len(wave)
        prompts = np.array([r.prompt for r in wave], np.int32)
        if n < self.slots:  # pad to engine width
            prompts = np.pad(prompts, ((0, self.slots - n), (0, 0)))
        batch = {"tokens": jnp.asarray(prompts)}
        cache, pos, last_logits = self._prefill(self.params, batch)
        tok = jnp.argmax(last_logits[:, 0], axis=-1).astype(jnp.int32)
        for i, r in enumerate(wave):
            r.out.append(int(tok[i]))
        max_new = max(r.max_new for r in wave)
        for t in range(max_new - 1):
            logits, cache = self._decode(self.params, cache, tok, pos + t)
            self.engine_steps += 1
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for i, r in enumerate(wave):
                if len(r.out) < r.max_new:
                    r.out.append(int(tok[i]))


def serve(model: Model, params, requests: List[Request], slots: int,
          cap: int, refresh=None) -> Dict:
    """Serve ``requests`` in waves. ``refresh`` (nullary, returns True on
    change) is polled *between* waves — the hook for schedule-snapshot hot
    reload: a fleet republish lands in a long-running serve process at the
    next wave boundary, no restart, and never mid-wave."""
    engine = ServeEngine(model, params, slots, cap)
    reloads = 0
    t0 = time.perf_counter()
    for i in range(0, len(requests), slots):
        if refresh is not None and i and refresh():
            reloads += 1
        engine.run_wave(requests[i: i + slots])
    wall = time.perf_counter() - t0
    toks = sum(len(r.out) for r in requests)
    return {"wall_s": wall, "tokens": toks,
            "tok_per_s": toks / max(wall, 1e-9),
            "engine_steps": engine.engine_steps,
            "cache_reloads": reloads}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--schedule-db", default=None,
                    help="warm repro.tuna schedule DB (JSONL) so trace-time "
                         "block-spec picks are lookups, not searches")
    ap.add_argument("--schedule-cache", default=None,
                    help="immutable schedule snapshot (python -m repro.tuna "
                         "snapshot); consulted before the DB — the lock-free "
                         "serving hot path. Accepts a versioned snapshot or "
                         "a SnapshotManager `latest` pointer; polled between "
                         "waves, so a republish lands without restart")
    ap.add_argument("--no-schedule-refresh", action="store_true",
                    help="do not poll the snapshot between waves (pin the "
                         "instance loaded at startup)")
    args = ap.parse_args()

    if args.schedule_db:
        from repro.kernels.ops import use_schedule_db

        use_schedule_db(args.schedule_db)
    if args.schedule_cache:
        from repro.kernels.ops import use_schedule_cache

        use_schedule_cache(args.schedule_cache)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [
        Request(i, list(rng.integers(0, cfg.vocab, args.prompt_len)),
                args.max_new)
        for i in range(args.requests)
    ]
    cap = args.prompt_len + args.max_new + 2
    # --schedule-cache or $REPRO_TUNA_CACHE both install a snapshot; either
    # way the serve loop polls for republishes (a stale/unbuilt env
    # snapshot resolves to OFF at startup and *heals* through the poll)
    import os

    cache_installed = bool(args.schedule_cache
                           or os.environ.get("REPRO_TUNA_CACHE"))
    refresh = None
    if cache_installed and not args.no_schedule_refresh:
        from repro.core import tuner

        def refresh():
            swapped = tuner.refresh_default_cache()
            if swapped:
                print("[serve] schedule snapshot republish observed — "
                      "hot-reloaded (hit counters reset)")
            return swapped

    stats = serve(model, params, reqs, slots=args.slots, cap=cap,
                  refresh=refresh)
    print(f"[serve] {stats['tokens']} tokens in {stats['wall_s']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s, {stats['engine_steps']} engine steps)")
    if cache_installed:
        from repro.core import tuner

        cache = tuner.get_default_cache()
        if cache is None:
            print("[serve] schedule cache: none installed (snapshot "
                  "missing or stale; republish to hot-load it)")
        else:
            print(f"[serve] schedule cache: {cache.hits} hits / "
                  f"{cache.misses} misses ({len(cache)} records, "
                  f"{stats['cache_reloads']} hot reloads)")


if __name__ == "__main__":
    main()
