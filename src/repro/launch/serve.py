"""Batched serving driver: continuous batching (default) or wave fallback.

Requests flow through one of two schedulers (design notes: README
"Serving" section — slot lifecycle, per-slot positions, refill
invariants):

* ``continuous`` (default) — ``launch/engine.py``: per-slot position
  vectors, an admission queue with per-request deadlines, and slot refill
  the moment a request finishes (EOS / ``max_new`` / deadline). Schedule
  snapshots hot-reload at admission boundaries.
* ``wave`` (fallback, for parity comparison) — waves of ``slots`` equal-
  length prompts prefill *batched* (the prefill path the dry-run lowers at
  32k) then decode in lockstep with a scalar position; a finished request
  parks its slot until the wave drains. Snapshot polls land between waves.

Both report per-request TTFT / end-to-end latency percentiles and
``wasted_slot_steps`` (slot-steps burned on pad/finished slots) so the
schedulers compare honestly — see ``benchmarks/serving_latency.py``.

CPU-scale demo:  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
    --reduced --requests 6 --slots 2 --max-new 16
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.launch.engine import (ContinuousEngine, Request, request_stats)
from repro.models.model import Model

__all__ = ["Request", "ServeEngine", "serve", "group_into_waves"]


def group_into_waves(requests: List[Request], slots: int) -> List[List[Request]]:
    """Bucket by prompt length (wave prefill is one batched launch, so a
    wave must be homogeneous), then chunk each bucket into waves of at most
    ``slots``. Submission order is preserved within a bucket; short tail
    waves get padded at run time — the honest cost the accounting exposes."""
    buckets: Dict[int, List[Request]] = {}
    for r in requests:
        buckets.setdefault(len(r.prompt), []).append(r)
    waves = []
    for length in buckets:
        group = buckets[length]
        waves.extend(group[i: i + slots] for i in range(0, len(group), slots))
    return waves


class ServeEngine:
    """Lockstep wave scheduler (the fallback baseline)."""

    def __init__(self, model: Model, params, slots: int, cap: int):
        self.model = model
        self.params = params
        self.slots = slots
        self.cap = cap
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, cap))
        self._decode = jax.jit(model.decode_step)
        self.engine_steps = 0        # decode launches
        self.slot_steps = 0          # slot-steps doing live work
        self.wasted_slot_steps = 0   # slot-steps on pad/finished slots
        self.prefills = 0
        self._t0 = time.perf_counter()

    def run_wave(self, wave: List[Request]) -> None:
        assert len({len(r.prompt) for r in wave}) == 1, "wave = equal prompts"
        n = len(wave)
        prompts = np.array([r.prompt for r in wave], np.int32)
        if n < self.slots:  # pad to engine width
            prompts = np.pad(prompts, ((0, self.slots - n), (0, 0)))
        batch = {"tokens": jnp.asarray(prompts)}
        cache, pos, last_logits = self._prefill(self.params, batch)
        self.prefills += 1
        tok = jnp.argmax(last_logits[:, 0], axis=-1).astype(jnp.int32)
        tok_np = np.asarray(tok)  # one host sync per step, not one per slot
        now = time.perf_counter() - self._t0
        for i, r in enumerate(wave):
            r.out.append(int(tok_np[i]))
            r.t_first = now
            if len(r.out) >= r.max_new:
                r.t_done = now
        max_new = max(r.max_new for r in wave)
        for t in range(max_new - 1):
            # pad rows (slots - n) and already-finished requests still run
            # the full decode step — that is the wave scheduler's cost; it
            # is *reported* as waste, never as engine work
            live = sum(1 for r in wave if len(r.out) < r.max_new)
            logits, cache = self._decode(self.params, cache, tok, pos + t)
            self.engine_steps += 1
            self.slot_steps += live
            self.wasted_slot_steps += self.slots - live
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            tok_np = np.asarray(tok)
            now = time.perf_counter() - self._t0
            for i, r in enumerate(wave):
                if len(r.out) < r.max_new:
                    r.out.append(int(tok_np[i]))
                    if len(r.out) >= r.max_new:
                        r.t_done = now


def serve(model: Model, params, requests: List[Request], slots: int,
          cap: int, refresh=None, scheduler: str = "continuous") -> Dict:
    """Serve ``requests`` with the chosen scheduler.

    ``refresh`` (nullary, returns True on change) is the schedule-snapshot
    hot-reload hook: a fleet republish lands in a long-running serve
    process with no restart. The wave scheduler polls it *between* waves
    (never mid-wave); the continuous engine polls at *admission*
    boundaries — the moment a new request enters the engine.
    """
    t0 = time.perf_counter()
    if scheduler == "continuous":
        engine = ContinuousEngine(model, params, slots, cap, refresh=refresh)
        engine.run(requests)
        stats = engine.stats()
    elif scheduler == "wave":
        engine = ServeEngine(model, params, slots, cap)
        reloads = 0
        for i, wave in enumerate(group_into_waves(requests, slots)):
            if refresh is not None and i and refresh():
                reloads += 1
            engine.run_wave(wave)
        stats = {"engine_steps": engine.engine_steps,
                 "slot_steps": engine.slot_steps,
                 "wasted_slot_steps": engine.wasted_slot_steps,
                 "prefills": engine.prefills,
                 "cache_reloads": reloads}
    else:
        raise ValueError(f"unknown scheduler: {scheduler!r}")
    wall = time.perf_counter() - t0
    toks = sum(len(r.out) for r in requests)
    stats.update({"scheduler": scheduler, "wall_s": wall, "tokens": toks,
                  "tok_per_s": toks / max(wall, 1e-9)})
    stats.update(request_stats(requests))
    return stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--scheduler", choices=("continuous", "wave"),
                    default="continuous",
                    help="continuous = per-slot positions + refill on free; "
                         "wave = lockstep fallback for parity comparison")
    ap.add_argument("--schedule-db", default=None,
                    help="warm repro.tuna schedule DB (JSONL) so trace-time "
                         "block-spec picks are lookups, not searches")
    ap.add_argument("--schedule-cache", default=None,
                    help="immutable schedule snapshot (python -m repro.tuna "
                         "snapshot); consulted before the DB — the lock-free "
                         "serving hot path. Accepts a versioned snapshot or "
                         "a SnapshotManager `latest` pointer; polled at "
                         "admission/wave boundaries, so a republish lands "
                         "without restart")
    ap.add_argument("--no-schedule-refresh", action="store_true",
                    help="do not poll the snapshot while serving (pin the "
                         "instance loaded at startup)")
    ap.add_argument("--kernel-bundle", default=None,
                    help="golden AOT kernel bundle (python -m repro.tuna "
                         "golden --bundle, or its `latest` pointer): the "
                         "first schedule-lookup tier, plus ahead-of-time "
                         "compiled executables so cold start performs zero "
                         "Pallas compilations for bundled kernels")
    args = ap.parse_args()

    if args.schedule_db:
        from repro.kernels.ops import use_schedule_db

        use_schedule_db(args.schedule_db)
    if args.schedule_cache:
        from repro.kernels.ops import use_schedule_cache

        use_schedule_cache(args.schedule_cache)
    if args.kernel_bundle:
        from repro.kernels.ops import use_kernel_bundle

        use_kernel_bundle(args.kernel_bundle)
        from repro.core import tuner as _tuner

        print(f"[serve] kernel bundle: {_tuner.get_default_bundle().describe()}")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [
        Request(i, list(rng.integers(0, cfg.vocab, args.prompt_len)),
                args.max_new)
        for i in range(args.requests)
    ]
    cap = args.prompt_len + args.max_new + 2
    # --schedule-cache or $REPRO_TUNA_CACHE both install a snapshot; either
    # way the serve loop polls for republishes (a stale/unbuilt env
    # snapshot resolves to OFF at startup and *heals* through the poll)
    import os

    cache_installed = bool(args.schedule_cache
                           or os.environ.get("REPRO_TUNA_CACHE"))
    refresh = None
    if cache_installed and not args.no_schedule_refresh:
        from repro.core import tuner

        def refresh():
            swapped = tuner.refresh_default_cache()
            if swapped:
                print("[serve] schedule snapshot republish observed — "
                      "hot-reloaded (hit counters reset)")
            return swapped

    stats = serve(model, params, reqs, slots=args.slots, cap=cap,
                  refresh=refresh, scheduler=args.scheduler)
    print(f"[serve] {stats['scheduler']}: {stats['tokens']} tokens in "
          f"{stats['wall_s']:.2f}s ({stats['tok_per_s']:.1f} tok/s, "
          f"{stats['engine_steps']} engine steps, "
          f"{stats['slot_steps']} live slot-steps, "
          f"{stats['wasted_slot_steps']} wasted)")
    print(f"[serve] ttft p50/p95/p99 = {stats['ttft_s']['p50']:.3f}/"
          f"{stats['ttft_s']['p95']:.3f}/{stats['ttft_s']['p99']:.3f}s; "
          f"latency p50/p95/p99 = {stats['latency_s']['p50']:.3f}/"
          f"{stats['latency_s']['p95']:.3f}/{stats['latency_s']['p99']:.3f}s")
    if cache_installed:
        from repro.core import tuner

        cache = tuner.get_default_cache()
        if cache is None:
            print("[serve] schedule cache: none installed (snapshot "
                  "missing or stale; republish to hot-load it)")
        else:
            print(f"[serve] schedule cache: {cache.hits} hits / "
                  f"{cache.misses} misses ({len(cache)} records, "
                  f"{stats['cache_reloads']} hot reloads)")
    if args.kernel_bundle:
        from repro.core import tuner
        from repro.kernels.ops import pallas_trace_counts

        bundle = tuner.get_default_bundle()
        traces = pallas_trace_counts()
        print(f"[serve] kernel bundle: {bundle.hits} schedule hits, "
              f"{bundle.exec_hits} AOT executable hits / "
              f"{bundle.exec_misses} misses; pallas traces this process: "
              f"matmul={traces['matmul']} flash={traces['flash']}")


if __name__ == "__main__":
    main()
