"""Abstract input specs (ShapeDtypeStruct) for every (arch × shape) cell.

Everything here is allocation-free: params/opt/cache structures come from
``jax.eval_shape``; batches are ShapeDtypeStructs. The dry-run lowers the
step functions against these and the production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import Model

SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def shape_applicable(cfg: ArchConfig, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: O(s^2) — long_500k skipped (DESIGN §4)"
    return True, ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ArchConfig, batch: int, seq: int) -> Dict[str, Any]:
    b: Dict[str, Any] = {
        "tokens": sds((batch, seq), jnp.int32),
        "labels": sds((batch, seq), jnp.int32),
    }
    if cfg.frontend == "audio":
        b["frames"] = sds((batch, cfg.n_frontend_tokens, cfg.d_model),
                          cfg.jnp_compute_dtype())
    if cfg.frontend == "vision":
        b["patches"] = sds((batch, cfg.n_frontend_tokens, cfg.d_model),
                           cfg.jnp_compute_dtype())
    return b


def infer_batch_specs(cfg: ArchConfig, batch: int, seq: int) -> Dict[str, Any]:
    b = batch_specs(cfg, batch, seq)
    b.pop("labels")
    return b


def abstract_params(model: Model):
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def abstract_cache(model: Model, batch: int, cap: int):
    return jax.eval_shape(lambda: model.init_cache(batch, cap))


def decode_specs(cfg: ArchConfig, batch: int, cap: int) -> Dict[str, Any]:
    return {
        "tokens": sds((batch,), jnp.int32),
        "pos": sds((), jnp.int32),
    }


def recommended_state_dtype(cfg: ArchConfig) -> str:
    """fp32 moments unless the arch can't fit them on a 256-chip pod."""
    n = cfg.param_count()
    # params(bf16) + m + v on 256 chips; leave most of the 16 GiB HBM for
    # gradients + activations + temp (EXPERIMENTS §Dry-run memory table)
    hbm = 16 * 1024**3
    if n * (2 + 8) / 256 < 0.30 * hbm:
        return "float32"
    if n * (2 + 4) / 256 < 0.40 * hbm:
        return "bfloat16"
    return "int8"
