"""Step functions (what gets jit-ed, lowered, and dry-run compiled)."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine
from repro.parallel import collectives


def make_train_step(
    model: Model,
    opt_cfg: adamw.AdamWConfig,
    *,
    accum_steps: int = 1,
    grad_compression: Optional[str] = None,  # None | "int8"
    schedule: Callable = warmup_cosine,
    grad_shardings=None,  # pytree of NamedSharding matching params
    grad_dtype=None,  # accumulate/reduce grads in this dtype (e.g. bf16)
) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    With ``accum_steps > 1`` the batch's leading dim is split into
    microbatches scanned sequentially (grad accumulation); pass
    ``grad_shardings`` so the f32 accumulator is sharded like the params
    (left to propagation XLA replicates it — 24 GiB/device at 6B scale).
    Optional int8 gradient compression quantises grads before the data-
    parallel reduction — see parallel/collectives.py.
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def _constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            def micro(carry, mb):
                gsum, msum = carry
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                if grad_dtype is not None:
                    g = jax.tree.map(lambda x: x.astype(grad_dtype), g)
                gsum = _constrain(jax.tree.map(jnp.add, gsum, g))
                msum = jax.tree.map(jnp.add, msum, m)
                return (gsum, msum), None

            mbs = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]),
                batch,
            )
            acc_dt = grad_dtype or jnp.float32
            zeros_g = _constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params
            ))
            zeros_m = {"ce": jnp.zeros((), jnp.float32),
                       "loss": jnp.zeros((), jnp.float32)}
            if model.cfg.moe is not None:
                zeros_m["aux"] = jnp.zeros((), jnp.float32)
            (grads, msum), _ = jax.lax.scan(micro, (zeros_g, zeros_m), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            metrics = jax.tree.map(lambda m: m / accum_steps, msum)

        if grad_compression == "int8":
            grads = collectives.int8_compress_decompress(grads)

        lr_scale = schedule(opt_state["step"])
        params, opt_state, om = adamw.apply_updates(
            opt_cfg, params, grads, opt_state, lr_scale=lr_scale
        )
        metrics = dict(metrics)
        metrics.update(om)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Model, cap: int) -> Callable:
    def prefill_step(params, batch):
        cache, pos, last_logits = model.prefill(params, batch, cap)
        return cache, pos, last_logits

    return prefill_step


def make_serve_step(model: Model) -> Callable:
    """One decode step for a whole batch of requests (continuous batching's
    inner loop): (params, cache, tokens [B], pos) -> (logits, new_cache)."""

    def serve_step(params, cache, tokens, pos):
        logits, new_cache = model.decode_step(params, cache, tokens, pos)
        return logits, new_cache

    return serve_step
