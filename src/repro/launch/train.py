"""Fault-tolerant training driver.

Single entry point for real runs and CPU-scale examples:

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Features exercised regardless of scale: deterministic resumable data,
async atomic checkpointing + keep-k GC, failure injection + bounded
restarts (restore from latest), straggler monitoring, heartbeats, optional
mesh + sharded state, grad accumulation, int8 grad compression.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import os
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.configs.base import get_config
from repro.checkpoint import store
from repro.data.loader import PrefetchLoader
from repro.data.synthetic import SyntheticConfig, SyntheticTokens
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod
from repro.models.model import Model
from repro.optim import adamw
from repro.parallel import context as pctx
from repro.parallel import sharding as sh
from repro.runtime.failure import FailureInjector, InjectedFailure, RestartPolicy
from repro.runtime.straggler import Heartbeat, StragglerMonitor


@dataclasses.dataclass
class TrainOptions:
    steps: int = 50
    batch: int = 8
    seq: int = 128
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 20
    keep: int = 3
    accum_steps: int = 1
    grad_compression: Optional[str] = None
    state_dtype: str = "float32"
    lr: float = 3e-4
    seed: int = 0
    mesh_shape: Optional[tuple] = None  # e.g. (2, 4) -> ('data','model')
    log_every: int = 10


def build_state(model: Model, opt_cfg: adamw.AdamWConfig, seed: int, mesh=None):
    params = model.init(jax.random.key(seed))
    opt_state = adamw.init_state(opt_cfg, params)
    if mesh is not None:
        p_sh = sh.params_sharding(params, mesh)
        o_sh = sh.opt_state_sharding(opt_state, params, mesh)
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt_state = jax.tree.map(
            jax.device_put, opt_state, o_sh,
            is_leaf=lambda x: isinstance(x, dict) and "q" in x,
        ) if opt_cfg.state_dtype == "int8" else jax.tree.map(
            jax.device_put, opt_state, o_sh
        )
    return params, opt_state


def train(cfg, opts: TrainOptions, injector: Optional[FailureInjector] = None,
          monitor: Optional[StragglerMonitor] = None) -> Dict[str, Any]:
    model = Model(cfg)
    opt_cfg = adamw.AdamWConfig(lr=opts.lr, state_dtype=opts.state_dtype)

    mesh = None
    if opts.mesh_shape:
        mesh = mesh_mod.make_mesh(opts.mesh_shape, ("data", "model"))
        pctx.install(("data",), tp_size=int(mesh.shape["model"]), sp_seq=False)

    params, opt_state = build_state(model, opt_cfg, opts.seed, mesh)
    p_sh = sh.params_sharding(params, mesh) if mesh is not None else None
    step_fn = steps_mod.make_train_step(
        model, opt_cfg, accum_steps=opts.accum_steps,
        grad_compression=opts.grad_compression, grad_shardings=p_sh,
    )
    jit_kwargs = {}
    if mesh is not None:
        batch_abstract = {
            "tokens": jax.ShapeDtypeStruct((opts.batch, opts.seq), np.int32),
            "labels": jax.ShapeDtypeStruct((opts.batch, opts.seq), np.int32),
        }
        o_sh = sh.opt_state_sharding(opt_state, params, mesh)
        jit_kwargs = dict(
            in_shardings=(p_sh, o_sh, sh.batch_sharding(batch_abstract, mesh)),
            out_shardings=(p_sh, o_sh, None),
        )
    jitted = jax.jit(step_fn, **jit_kwargs)

    start_step = 0
    ckpt = None
    if opts.ckpt_dir:
        ckpt = store.AsyncCheckpointer(opts.ckpt_dir, keep=opts.keep)
        latest = store.latest_step(opts.ckpt_dir)
        if latest is not None:
            (params, opt_state), manifest = store.restore(
                opts.ckpt_dir, (params, opt_state), step=latest
            )
            start_step = latest
            print(f"[train] resumed from step {start_step}")

    source = SyntheticTokens(
        SyntheticConfig(cfg.vocab, opts.seq, opts.batch, seed=opts.seed)
    )
    loader = PrefetchLoader(source, start_step=start_step)
    monitor = monitor or StragglerMonitor()
    hb = Heartbeat(os.path.join(opts.ckpt_dir, "HEARTBEAT")) if opts.ckpt_dir \
        else None

    history = []
    step = start_step
    try:
        while step < opts.steps:
            t0 = time.perf_counter()
            _, np_batch = loader.get(step)
            batch = {k: jax.numpy.asarray(v) for k, v in np_batch.items()}
            if injector:
                injector.maybe_fail(step, "step")
            params, opt_state, metrics = jitted(params, opt_state, batch)
            dt = time.perf_counter() - t0
            ev = monitor.record(step, dt, loader.fetch_seconds.get(step, 0.0))
            if ev:
                print(f"[straggler] step {step}: {ev.mitigation} "
                      f"({ev.step_seconds:.2f}s vs median {ev.median_seconds:.2f}s)")
            if hb:
                hb.beat(step)
            step += 1
            if step % opts.log_every == 0 or step == opts.steps:
                loss = float(metrics["loss"])
                history.append((step, loss, dt))
                print(f"[train] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            if ckpt and (step % opts.ckpt_every == 0 or step == opts.steps):
                if injector:
                    injector.maybe_fail(step, "save")
                ckpt.save(step, (params, opt_state), meta={"loss": float(
                    metrics["loss"])})
    finally:
        loader.close()
        if ckpt:
            ckpt.wait()
    return {"params": params, "opt_state": opt_state, "history": history,
            "final_step": step}


def train_with_recovery(cfg, opts: TrainOptions,
                        injector: Optional[FailureInjector] = None,
                        policy: Optional[RestartPolicy] = None) -> Dict[str, Any]:
    """Outer supervision loop: on failure, restart from latest checkpoint."""
    policy = policy or RestartPolicy()
    while True:
        try:
            return train(cfg, opts, injector=injector)
        except InjectedFailure as e:  # noqa: PERF203
            print(f"[recovery] {e}; restarting "
                  f"({policy.restarts + 1}/{policy.max_restarts})")
            if not policy.should_restart(e):
                raise


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-compression", choices=["int8"], default=None)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x4 (needs XLA_FLAGS host devices)")
    ap.add_argument("--schedule-db", default=None,
                    help="warm repro.tuna schedule DB (JSONL); kernel "
                         "block-spec picks become pure lookups")
    args = ap.parse_args()

    if args.schedule_db:
        from repro.kernels.ops import use_schedule_db

        use_schedule_db(args.schedule_db)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opts = TrainOptions(
        steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        accum_steps=args.accum, lr=args.lr,
        grad_compression=args.grad_compression,
        mesh_shape=tuple(int(x) for x in args.mesh.split("x")) if args.mesh
        else None,
    )
    out = train_with_recovery(cfg, opts)
    print(f"done at step {out['final_step']}; "
          f"last loss {out['history'][-1][1] if out['history'] else float('nan'):.4f}")


if __name__ == "__main__":
    main()
