"""GQA attention: train/prefill (flash on TPU, chunked-jnp elsewhere) and
single-token decode over a KV cache (flash-decode-style when the cache is
sequence-sharded).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import layers as L

NEG_INF = -1e30


def init_attention(cfg, rng, cross: bool = False) -> Dict:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.jnp_param_dtype()
    ks = jax.random.split(rng, 4)
    sc = d ** -0.5
    p = {
        "wq": L.normal(ks[0], (d, h * dh), sc, dt),
        "wk": L.normal(ks[1], (d, hkv * dh), sc, dt),
        "wv": L.normal(ks[2], (d, hkv * dh), sc, dt),
        "wo": L.normal(ks[3], (h * dh, d), (h * dh) ** -0.5, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dt)
        p["bk"] = jnp.zeros((hkv * dh,), dt)
        p["bv"] = jnp.zeros((hkv * dh,), dt)
    return p


def _project_qkv(cfg, p, x, kv_x=None):
    """x [B,S,D] -> q [B,H,S,dh], k/v [B,Hkv,Skv,dh]."""
    cd = cfg.jnp_compute_dtype()
    b, s, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    skv = kv_x.shape[1]
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x.astype(cd) @ p["wq"].astype(cd)
    k = kv_x.astype(cd) @ p["wk"].astype(cd)
    v = kv_x.astype(cd) @ p["wv"].astype(cd)
    if "bq" in p:
        q, k, v = q + p["bq"].astype(cd), k + p["bk"].astype(cd), v + p["bv"].astype(cd)
    q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, skv, hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, skv, hkv, dh).transpose(0, 2, 1, 3)
    return q, k, v


def chunked_attention(
    q: jax.Array,  # [B,H,S,dh]
    k: jax.Array,  # [B,Hkv,Skv,dh]
    v: jax.Array,
    causal: bool,
    chunk: int = 512,
) -> jax.Array:
    """Online-softmax attention scanned over KV chunks — the jnp mirror of the
    Pallas flash kernel, with O(S·chunk) peak memory. Scores/softmax run in
    f32; the two big einsums take bf16 operands with f32 accumulation, so the
    dominant transient is one [.., S, chunk] f32 score block."""
    b, h, s, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = h // hkv
    cd = q.dtype
    qg = (q.astype(jnp.float32) * (dh ** -0.5)).astype(cd).reshape(
        b, hkv, g, s, dh
    )
    c = min(chunk, skv)
    while skv % c:
        c //= 2
    nc = skv // c
    kc = k.reshape(b, hkv, nc, c, dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, nc, c, dh).transpose(2, 0, 1, 3, 4)
    q_pos = jnp.arange(s)

    @jax.checkpoint  # drop per-chunk score residuals (recompute in bwd)
    def body(carry, inp):
        m, l, acc, ci = carry
        ki, vi = inp
        s_ij = jnp.einsum("bhgqd,bhkd->bhgqk", qg, ki,
                          preferred_element_type=jnp.float32)
        if causal:
            k_pos = ci * c + jnp.arange(c)
            mask = q_pos[:, None] >= k_pos[None, :]
            s_ij = jnp.where(mask[None, None, None], s_ij, NEG_INF)
        m_new = jnp.maximum(m, s_ij.max(-1))
        p_ij = jnp.exp(s_ij - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p_ij.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p_ij.astype(cd), vi,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new, ci + 1), None

    m0 = jnp.full((b, hkv, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, s, dh), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, a0, jnp.zeros((), jnp.int32)),
                                     (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, s, dh).astype(q.dtype)


def attention_forward(
    cfg,
    p: Dict,
    x: jax.Array,
    positions: jax.Array,
    causal: bool = True,
    kv_x: Optional[jax.Array] = None,
    use_rope: bool = True,
    chunked_threshold: int = 4096,
) -> jax.Array:
    """Self (or cross, via kv_x) attention for train/prefill. Returns
    (output [B,S,D], (k, v) for cache)."""
    q, k, v = _project_qkv(cfg, p, x, kv_x)
    if use_rope and kv_x is None:
        sin, cos = L.rope_tables(cfg, positions)  # [S, dh/2] — broadcasts
        q = L.apply_rope(q, sin, cos)
        k = L.apply_rope(k, sin, cos)
    s = q.shape[2]
    if kv_x is not None:
        out = chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    elif s >= chunked_threshold:
        out = chunked_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    else:
        out = kops.attention(q, k, v, causal=causal)
    b = x.shape[0]
    cd = cfg.jnp_compute_dtype()
    merged = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    y = merged.astype(cd) @ p["wo"].astype(cd)
    return y.astype(x.dtype), (k, v)


def decode_attention(
    cfg,
    p: Dict,
    x: jax.Array,  # [B, 1, D]
    cache_k: jax.Array,  # [B, Hkv, CAP, dh]
    cache_v: jax.Array,
    pos: jax.Array,  # scalar i32 (lockstep) or [B] i32 (per-slot depths)
    cross: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token attention over the cache; returns (y, new_k, new_v).

    ``pos`` is the index where each new token sits. A scalar means every
    row decodes at the same depth (wave scheduling); a ``[B]`` vector gives
    each slot its own depth (continuous batching) — RoPE angles, the cache
    write index, and the validity mask are then all per-slot, so rows at
    different sequence lengths share one decode launch.

    For cross-attention the cache is the (static) encoder projection and no
    update happens. The einsums reduce over the cache's sequence axis — when
    that axis is sharded (long-context SP), XLA turns the reductions into
    partial sums + all-reduce: a flash-decode combine."""
    cd = cfg.jnp_compute_dtype()
    b = x.shape[0]
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // hkv
    cap = cache_k.shape[2]
    vector_pos = (not cross) and pos.ndim == 1

    q = (x.astype(cd) @ p["wq"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
    q = q.reshape(b, h, dh)  # S=1 folded away

    if not cross:
        knew = (x.astype(cd) @ p["wk"].astype(cd))
        vnew = (x.astype(cd) @ p["wv"].astype(cd))
        if "bk" in p:
            knew, vnew = knew + p["bk"].astype(cd), vnew + p["bv"].astype(cd)
        knew = knew.reshape(b, hkv, 1, dh)
        vnew = vnew.reshape(b, hkv, 1, dh)
        if vector_pos:
            # per-row tables [B, 1, dh/2]; lift to [B, 1, 1, dh/2] so they
            # broadcast over the head axis of q [B, H, 1, dh] / knew
            sin, cos = L.rope_tables(cfg, pos[:, None].astype(jnp.int32))
            sin, cos = sin[:, None], cos[:, None]
        else:
            sin, cos = L.rope_tables(cfg, pos[None].astype(jnp.int32))  # [1, dh/2]
        q = L.apply_rope(q.reshape(b, h, 1, dh), sin, cos).reshape(b, h, dh)
        knew = L.apply_rope(knew, sin, cos)
        if vector_pos:
            upd = jax.vmap(
                lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, axis=1)
            )
            cache_k = upd(cache_k, knew.astype(cache_k.dtype), pos)
            cache_v = upd(cache_v, vnew.astype(cache_v.dtype), pos)
        else:
            cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, knew.astype(cache_k.dtype), pos, axis=2)
            cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, vnew.astype(cache_v.dtype), pos, axis=2)

    qg = q.reshape(b, hkv, g, dh).astype(jnp.float32) * (dh ** -0.5)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qg, cache_k.astype(jnp.float32))
    idx = jnp.arange(cap)
    if cross:
        valid = jnp.ones((b, 1, 1, cap), bool)
    elif vector_pos:
        valid = (idx[None, :] <= pos[:, None])[:, None, None]  # [B,1,1,cap]
    else:
        valid = (idx <= pos)[None, None, None]
    logits = jnp.where(valid, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", w, cache_v.astype(jnp.float32))
    merged = out.reshape(b, 1, h * dh).astype(cd)
    y = merged @ p["wo"].astype(cd)
    return y.astype(x.dtype), cache_k, cache_v
