"""Shared neural-net layers (pure functions over param pytrees)."""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp


def normal(rng, shape, scale, dtype):
    return (scale * jax.random.normal(rng, shape, jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def init_norm(cfg, rng=None) -> Dict:
    d = cfg.d_model
    dt = cfg.jnp_param_dtype()
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), dt), "b": jnp.zeros((d,), dt)}
    return {"w": jnp.ones((d,), dt)}


def apply_norm(cfg, p: Dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "b" in p:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        return (y * p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(
            x.dtype
        )
    ms = (xf * xf).mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
    return (y * p["w"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------


def rope_tables(cfg, positions: jax.Array, d: Optional[int] = None):
    """positions [.. S] -> (sin, cos) each [..., S, d/2] in f32."""
    d = d or cfg.head_dim
    half = d // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x [..., S, D]; rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def init_dense_mlp(cfg, rng, d_ff: Optional[int] = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.jnp_param_dtype()
    ks = jax.random.split(rng, 3)
    sc_in, sc_out = d ** -0.5, f ** -0.5
    p = {
        "w1": normal(ks[0], (d, f), sc_in, dt),
        "w2": normal(ks[1], (f, d), sc_out, dt),
    }
    if cfg.activation in ("swiglu", "geglu"):
        p["w3"] = normal(ks[2], (d, f), sc_in, dt)
    return p


def _act(cfg, h: jax.Array, g: Optional[jax.Array]) -> jax.Array:
    if cfg.activation == "swiglu":
        return jax.nn.silu(h) * g
    if cfg.activation == "geglu":
        return jax.nn.gelu(h) * g
    if cfg.activation == "sq_relu":
        r = jax.nn.relu(h)
        return r * r
    return jax.nn.gelu(h)


def apply_dense_mlp(cfg, p: Dict, x: jax.Array) -> jax.Array:
    cd = cfg.jnp_compute_dtype()
    h = x.astype(cd) @ p["w1"].astype(cd)
    g = x.astype(cd) @ p["w3"].astype(cd) if "w3" in p else None
    return (_act(cfg, h, g) @ p["w2"].astype(cd)).astype(x.dtype)


# --------------------------------------------------------------------------
# embedding / unembedding
# --------------------------------------------------------------------------


def init_embed(cfg, rng) -> Dict:
    dt = cfg.jnp_param_dtype()
    ks = jax.random.split(rng, 2)
    p = {"tok": normal(ks[0], (cfg.vocab, cfg.d_model), 0.02, dt)}
    if not cfg.tie_embeddings:
        p["head"] = normal(ks[1], (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5, dt)
    return p


def embed(cfg, p: Dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0).astype(cfg.jnp_compute_dtype())


def unembed(cfg, p: Dict, x: jax.Array) -> jax.Array:
    cd = cfg.jnp_compute_dtype()
    w = p["head"] if "head" in p else p["tok"].T
    return x.astype(cd) @ w.astype(cd)


def cross_entropy_loss(
    cfg, p: Dict, x: jax.Array, labels: jax.Array, seq_chunk: int = 1024
) -> jax.Array:
    """Chunked softmax-xent: never materialises [B, S, V] — the sequence is
    scanned in chunks (vocab stays shardable over the model axis)."""
    b, s, d = x.shape
    c = min(seq_chunk, s)
    while s % c:
        c //= 2
    nchunk = s // c
    xc = x.reshape(b, nchunk, c, d).swapaxes(0, 1)  # [nchunk, B, c, d]
    yc = labels.reshape(b, nchunk, c).swapaxes(0, 1)

    @jax.checkpoint  # recompute chunk logits in backward: no [B,c,V] residual
    def body(tot, xy):
        xi, yi = xy
        logits = unembed(cfg, p, xi).astype(jnp.float32)  # [B, c, V]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yi[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, yc))
    return total / (b * s)
