"""Model facade: init / loss / prefill / decode for every arch family.

Batch schemas (all arrays device-shardable):
  LM families:  {"tokens": [B,S] i32, "labels": [B,S] i32}
  audio:        + {"frames": [B, n_frontend_tokens, D]}     (STUB frontend)
  vlm:          + {"patches": [B, n_frontend_tokens, D]}    (STUB frontend)

Decode state (``DecodeState``) carries the per-layer cache tuple, the
position (scalar for lockstep waves, ``[B]`` for per-slot continuous
batching), and (enc-dec only) cross-attention caches built at prefill.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.parallel import context as pctx


@dataclasses.dataclass
class Model:
    cfg: Any

    # ------------------------------------------------------------------ init
    def init(self, rng) -> Dict:
        cfg = self.cfg
        ks = jax.random.split(rng, 4)
        params: Dict[str, Any] = {
            "embed": L.init_embed(cfg, ks[0]),
            "norm_f": L.init_norm(cfg),
            "layers": T.init_stack(cfg, ks[1], cross=cfg.encoder_decoder),
        }
        if cfg.encoder_decoder:
            enc_pattern = (("attention", "dense"),)
            params["encoder"] = T.init_stack(
                cfg, ks[2], n_layers=cfg.n_encoder_layers, pattern=enc_pattern
            )
            params["enc_norm_f"] = L.init_norm(cfg)
            params["enc_pos"] = L.normal(
                ks[3], (cfg.n_frontend_tokens, cfg.d_model), 0.02,
                cfg.jnp_param_dtype(),
            )
        if cfg.frontend == "vision":
            params["vis_proj"] = L.normal(
                ks[3], (cfg.d_model, cfg.d_model), cfg.d_model ** -0.5,
                cfg.jnp_param_dtype(),
            )
        return params

    # --------------------------------------------------------------- helpers
    def _encode(self, params, frames):
        """Whisper encoder over stub frame embeddings [B, T, D]."""
        cfg = self.cfg
        x = frames.astype(cfg.jnp_compute_dtype()) + params["enc_pos"].astype(
            cfg.jnp_compute_dtype()
        )
        pos = jnp.arange(frames.shape[1])
        x, _, _ = T.apply_stack(cfg, params["encoder"], x, pos, causal=False,
                                pattern=(("attention", "dense"),))
        return L.apply_norm(cfg, params["enc_norm_f"], x)

    def _embed_inputs(self, params, batch) -> Tuple[jax.Array, jax.Array, int]:
        """Returns (x [B, S_total, D], positions, n_prefix)."""
        cfg = self.cfg
        x = L.embed(cfg, params["embed"], batch["tokens"])
        n_prefix = 0
        if cfg.frontend == "vision":
            cd = cfg.jnp_compute_dtype()
            patches = batch["patches"].astype(cd) @ params["vis_proj"].astype(cd)
            x = jnp.concatenate([patches, x], axis=1)
            n_prefix = patches.shape[1]
        pos = jnp.arange(x.shape[1])
        return pctx.constrain_tokens(x), pos, n_prefix

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        enc_out = None
        if cfg.encoder_decoder:
            enc_out = self._encode(params, batch["frames"])
        x, pos, n_prefix = self._embed_inputs(params, batch)
        x, _, aux = T.apply_stack(cfg, params["layers"], x, pos, causal=True,
                                  enc_out=enc_out)
        x = L.apply_norm(cfg, params["norm_f"], x)
        if n_prefix:
            x = x[:, n_prefix:]
        ce = L.cross_entropy_loss(cfg, params["embed"], x, batch["labels"])
        total = ce
        metrics = {"ce": ce}
        if cfg.moe is not None:
            total = total + cfg.moe.router_aux_weight * aux
            metrics["aux"] = aux
        metrics["loss"] = total
        return total, metrics

    def forward_hidden(self, params, batch) -> jax.Array:
        """Final hidden states (used by tests)."""
        cfg = self.cfg
        enc_out = self._encode(params, batch["frames"]) if cfg.encoder_decoder else None
        x, pos, _ = self._embed_inputs(params, batch)
        x, _, _ = T.apply_stack(cfg, params["layers"], x, pos, causal=True,
                                enc_out=enc_out)
        return L.apply_norm(cfg, params["norm_f"], x)

    def logits(self, params, batch) -> jax.Array:
        x = self.forward_hidden(params, batch)
        n_prefix = self.cfg.n_frontend_tokens if self.cfg.frontend == "vision" else 0
        if n_prefix:
            x = x[:, n_prefix:]
        return L.unembed(self.cfg, params["embed"], x)

    # --------------------------------------------------------------- serving
    def init_cache(self, batch: int, cap: int) -> Tuple:
        cfg = self.cfg
        cross_len = cfg.n_frontend_tokens if cfg.encoder_decoder else 0
        return T.init_stack_cache(cfg, batch, cap, cross_len=cross_len)

    def prefill(self, params, batch, cap: int):
        """Run the prompt, build a decode cache of capacity ``cap``.
        Returns (cache, pos_next, last_logits)."""
        cfg = self.cfg
        enc_out = self._encode(params, batch["frames"]) if cfg.encoder_decoder else None
        x, pos, n_prefix = self._embed_inputs(params, batch)
        s_total = x.shape[1]
        assert cap >= s_total, (cap, s_total)
        x, caches, _ = T.apply_stack(cfg, params["layers"], x, pos, causal=True,
                                     enc_out=enc_out, collect_cache=True)
        x = L.apply_norm(cfg, params["norm_f"], x)

        def pad_cache(leaf):
            # attention k/v: [G, B, Hkv, S, dh] -> capacity cap on axis 3
            if leaf.ndim == 5 and leaf.shape[3] == s_total:
                pad = [(0, 0)] * 5
                pad[3] = (0, cap - s_total)
                return jnp.pad(leaf, pad)
            return leaf

        caches = jax.tree.map(pad_cache, caches)
        last_logits = L.unembed(cfg, params["embed"], x[:, -1:])
        return caches, jnp.asarray(s_total, jnp.int32), last_logits

    def decode_step(self, params, cache, token: jax.Array, pos: jax.Array):
        """token [B] i32; pos scalar i32 (all rows at the same depth) or
        [B] i32 (per-slot depths — continuous batching, each row attends,
        ropes and cache-writes at its own position).
        Returns (logits [B, V], new_cache)."""
        cfg = self.cfg
        x = L.embed(cfg, params["embed"], token[:, None])
        x, new_cache = T.apply_stack_decode(cfg, params["layers"], x, cache, pos)
        x = L.apply_norm(cfg, params["norm_f"], x)
        logits = L.unembed(cfg, params["embed"], x)[:, 0]
        return logits, new_cache
