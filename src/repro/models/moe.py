"""Mixture-of-Experts MLP: top-k routing, capacity-bounded gather dispatch,
expert-parallel over the ``model`` mesh axis.

Dispatch is gather/scatter-based (no [T, E, C] one-hot einsum): per batch-row
group, each expert receives a capacity-C gather of token vectors; compute is
a pair of einsums with the expert dim sharded (EP); the scatter-add combine
produces partial sums that XLA reduces over the model axis. Tokens routed
beyond capacity are dropped (Switch-style), bounded by ``capacity_factor``.

Aux loss: Switch load-balancing  E · Σ_e f_e · P_e.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.parallel import context as pctx


def init_moe(cfg, rng) -> Dict:
    moe = cfg.moe
    d, fe, e = cfg.d_model, moe.d_expert, moe.n_experts
    dt = cfg.jnp_param_dtype()
    ks = jax.random.split(rng, 5)
    sc_in, sc_out = d ** -0.5, fe ** -0.5
    p = {
        "router": L.normal(ks[0], (d, e), sc_in, dt),
        "w1": L.normal(ks[1], (e, d, fe), sc_in, dt),
        "w2": L.normal(ks[2], (e, fe, d), sc_out, dt),
    }
    if cfg.activation in ("swiglu", "geglu"):
        p["w3"] = L.normal(ks[3], (e, d, fe), sc_in, dt)
    if moe.shared_expert:
        p["shared"] = L.init_dense_mlp(cfg, ks[4], d_ff=fe)
    return p


def route(cfg, p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [B,S,D] -> (topk_idx [B,S,k], gates [B,S,k], aux_loss scalar)."""
    moe = cfg.moe
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, moe.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch aux loss: fraction of tokens per expert x mean router prob
    e = moe.n_experts
    assign = jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32)  # top-1 assignment
    f = assign.mean(axis=(0, 1))
    pbar = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(f * pbar)
    return idx, gates.astype(x.dtype), aux


def apply_moe(cfg, p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,D], aux_loss)."""
    moe = cfg.moe
    b, s, d = x.shape
    e, k = moe.n_experts, moe.top_k
    cap = max(1, int(s * k * moe.capacity_factor / e))
    cd = cfg.jnp_compute_dtype()

    idx, gates, aux = route(cfg, p, x)

    # ---- capacity assignment (per batch row), sort-based ----------------
    # position of an assignment within its expert = its rank among equal
    # expert ids, computed by stable sort + segment-start cummax: O(T·k)
    # memory (the one-hot/cumsum alternative is O(T·k·E) — 16 GiB at 94-layer
    # MoE scale).
    flat_e = idx.reshape(b, s * k).astype(jnp.int32)  # expert id per slot
    ar = jnp.arange(s * k, dtype=jnp.int32)

    def ranks_one(fe):
        order = jnp.argsort(fe, stable=True)
        sorted_e = fe[order]
        is_start = jnp.concatenate(
            [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]]
        )
        seg_start = jax.lax.cummax(jnp.where(is_start, ar, 0))
        rank_sorted = ar - seg_start
        return jnp.zeros_like(fe).at[order].set(rank_sorted)

    pos_flat = jax.vmap(ranks_one)(flat_e)  # [B, S*k]
    keep = pos_flat < cap

    token_of_slot = jnp.repeat(jnp.arange(s), k)[None].astype(jnp.int32)  # [1, S*k]
    token_of_slot = jnp.broadcast_to(token_of_slot, (b, s * k))
    gate_of_slot = gates.reshape(b, s * k)

    # dispatch_idx [B, E, C]: source token for each capacity slot (0 if unused)
    dispatch_idx = jnp.zeros((b, e, cap), jnp.int32)
    slot_w = jnp.zeros((b, e, cap), cd)
    bidx = jnp.arange(b)[:, None]
    e_clip = jnp.where(keep, flat_e, 0)
    c_clip = jnp.where(keep, pos_flat, 0)
    dispatch_idx = dispatch_idx.at[bidx, e_clip, c_clip].set(
        jnp.where(keep, token_of_slot, 0), mode="drop"
    )
    slot_w = slot_w.at[bidx, e_clip, c_clip].set(
        jnp.where(keep, gate_of_slot, 0).astype(cd), mode="drop"
    )
    # pin the dispatch plan to batch-over-DP (the index tensors are small —
    # constraining their expert dim over TP forces extra gathers; only the
    # big [B,E,C,*] activations get the (dp, tp) pin): without this GSPMD
    # replicates the gather/scatter across DP and all-reduces the f32
    # backward intermediates — 2.7 TB/device/step on qwen3-moe (§Perf)
    if pctx.moe_pin():
        dispatch_idx = pctx.constrain_dims(dispatch_idx, ("dp", None, None))
        slot_w = pctx.constrain_dims(slot_w, ("dp", None, None))

    # ---- gather -> expert compute (EP over model axis) -----------------
    xin = jax.vmap(lambda xb, ib: xb[ib])(x, dispatch_idx)  # [B,E,C,D]
    if pctx.moe_pin():
        xin = pctx.constrain_dims(xin, ("dp", "tp", None, None))
    xin = xin * (slot_w[..., None] != 0)  # zero out unused slots
    h = jnp.einsum("becd,edf->becf", xin.astype(cd), p["w1"].astype(cd))
    if pctx.moe_pin():
        h = pctx.constrain_dims(h, ("dp", "tp", None, None))
    if "w3" in p:
        g = jnp.einsum("becd,edf->becf", xin.astype(cd), p["w3"].astype(cd))
        h = jax.nn.silu(h) * g if cfg.activation == "swiglu" else jax.nn.gelu(h) * g
    else:
        r = jax.nn.relu(h)
        h = r * r if cfg.activation == "sq_relu" else jax.nn.gelu(h)
    out = jnp.einsum("becf,efd->becd", h, p["w2"].astype(cd))  # [B,E,C,D]
    if pctx.moe_pin():
        out = pctx.constrain_dims(out, ("dp", "tp", None, None))
    out = out * slot_w[..., None]

    # ---- scatter-add combine -------------------------------------------
    y = jnp.zeros((b, s, d), cd)
    y = y.at[bidx[..., None], dispatch_idx].add(out, mode="drop")
    if pctx.moe_pin():
        y = pctx.constrain_dims(y, ("dp", None, None))

    if moe.shared_expert:
        y = y + L.apply_dense_mlp(cfg, p["shared"], x).astype(cd)
    return y.astype(x.dtype), aux.astype(jnp.float32)
