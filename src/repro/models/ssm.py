"""Mamba (S6) selective-SSM mixer.

Training/prefill uses a chunked scan: ``lax.scan`` over sequence chunks with
an associative scan inside each chunk, so the discretised [B, chunk, d_inner,
N] tensors stay bounded (the jamba long-context path depends on this).
Decode carries (conv_state [B, K-1, d_inner], ssm_state [B, d_inner, N]).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


def d_inner(cfg) -> int:
    return cfg.ssm_expand * cfg.d_model


def init_mamba(cfg, rng) -> Dict:
    d, di, n = cfg.d_model, d_inner(cfg), cfg.ssm_state
    k = cfg.ssm_conv
    dt_rank = max(1, d // 16)
    dt = cfg.jnp_param_dtype()
    ks = jax.random.split(rng, 7)
    p = {
        "in_proj": L.normal(ks[0], (d, 2 * di), d ** -0.5, dt),
        "conv_w": L.normal(ks[1], (k, di), k ** -0.5, dt),
        "conv_b": jnp.zeros((di,), dt),
        "w_bc": L.normal(ks[2], (di, 2 * n), di ** -0.5, dt),
        "w_dt": L.normal(ks[3], (di, dt_rank), di ** -0.5, dt),
        "dt_proj": L.normal(ks[4], (dt_rank, di), dt_rank ** -0.5, dt),
        "dt_bias": jnp.full((di,), -4.6, dt),  # softplus^-1(0.01)
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
        ).astype(dt),
        "D": jnp.ones((di,), dt),
        "out_proj": L.normal(ks[5], (di, d), di ** -0.5, dt),
    }
    return p


def _discretise(p, x):
    """x [..., di] -> (dA [..., di, N], dBx [..., di, N]) in f32."""
    xf = x.astype(jnp.float32)
    bc = xf @ p["w_bc"].astype(jnp.float32)  # [..., 2N]
    n = bc.shape[-1] // 2
    b_t, c_t = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(
        (xf @ p["w_dt"].astype(jnp.float32)) @ p["dt_proj"].astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )  # [..., di]
    a = -jnp.exp(p["A_log"].astype(jnp.float32))  # [di, N]
    dA = jnp.exp(dt[..., None] * a)  # [..., di, N]
    dBx = (dt * xf)[..., None] * b_t[..., None, :]  # [..., di, N]
    return dA, dBx, c_t


def _chunk_scan(carry_h, dA, dBx):
    """Associative scan within a chunk. dA/dBx [B, C, di, N]; h0 [B, di, N]."""

    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, b1 * a2 + b2

    dA_s, dBx_s = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h = dA_s * carry_h[:, None] + dBx_s  # [B, C, di, N]
    return h, h[:, -1]


def mamba_forward(cfg, p: Dict, x: jax.Array, chunk: int = 0,
                  return_state: bool = False):
    """Train/prefill path. x [B, S, D] -> [B, S, D] (+ final decode cache
    when ``return_state``)."""
    b, s, d = x.shape
    chunk = chunk or cfg.ssm_chunk
    di = d_inner(cfg)
    cd = cfg.jnp_compute_dtype()
    k = cfg.ssm_conv

    xz = x.astype(cd) @ p["in_proj"].astype(cd)  # [B, S, 2di]
    xi, z = xz[..., :di], xz[..., di:]
    # causal depthwise conv (width k)
    pad = jnp.zeros((b, k - 1, di), xi.dtype)
    xp = jnp.concatenate([pad, xi], axis=1)
    conv = sum(
        xp[:, i : i + s, :] * p["conv_w"].astype(cd)[i] for i in range(k)
    ) + p["conv_b"].astype(cd)
    u = jax.nn.silu(conv)  # [B, S, di]

    c = min(chunk, s)
    while s % c:
        c //= 2
    nc = s // c
    uc = u.reshape(b, nc, c, di).swapaxes(0, 1)  # [nc, B, c, di]

    @jax.checkpoint  # recompute discretised tensors in bwd
    def body(h, u_i):
        dA, dBx, c_t = _discretise(p, u_i)  # [B, c, di, N]
        hs, h_last = _chunk_scan(h, dA, dBx)
        y = jnp.einsum("bcdn,bcn->bcd", hs, c_t)  # [B, c, di]
        return h_last, y

    h0 = jnp.zeros((b, di, cfg.ssm_state), jnp.float32)
    h_last, ys = jax.lax.scan(body, h0, uc)
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    y = y + u.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = y.astype(cd) * jax.nn.silu(z)
    out = (y @ p["out_proj"].astype(cd)).astype(x.dtype)
    if return_state:
        conv_tail = jax.lax.dynamic_slice_in_dim(xp, s, k - 1, axis=1)
        return out, {"conv": conv_tail, "h": h_last}
    return out


def init_mamba_cache(cfg, batch: int, dtype) -> Dict:
    di = d_inner(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    }


def mamba_decode(cfg, p: Dict, x: jax.Array, cache: Dict) -> Tuple[jax.Array, Dict]:
    """One token. x [B, 1, D] -> (y [B, 1, D], new cache)."""
    b = x.shape[0]
    di = d_inner(cfg)
    cd = cfg.jnp_compute_dtype()
    k = cfg.ssm_conv

    xz = x[:, 0].astype(cd) @ p["in_proj"].astype(cd)
    xi, z = xz[..., :di], xz[..., di:]
    window = jnp.concatenate([cache["conv"], xi[:, None]], axis=1)  # [B, k, di]
    conv = (
        jnp.einsum("bkd,kd->bd", window.astype(cd), p["conv_w"].astype(cd))
        + p["conv_b"].astype(cd)
    )
    u = jax.nn.silu(conv)  # [B, di]
    dA, dBx, c_t = _discretise(p, u)  # [B, di, N], [B, N]
    h = cache["h"] * dA + dBx
    y = jnp.einsum("bdn,bn->bd", h, c_t)
    y = y + u.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = y.astype(cd) * jax.nn.silu(z)
    out = (y @ p["out_proj"].astype(cd)).astype(x.dtype)
    return out[:, None], {"conv": window[:, 1:], "h": h}
