"""Heterogeneous block stacking.

A stack is grouped by the config's block-pattern *period*: layer i uses
pattern position ``i % period``; parameters for each period position are
stacked over the ``n_layers/period`` groups and the whole stack runs as one
``lax.scan`` over groups (HLO size stays O(period) regardless of depth — the
94-layer dry-runs depend on this), with per-group remat.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.parallel import context as pctx


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------


def init_block(cfg, rng, mixer_kind: str, mlp_kind: str, cross: bool = False) -> Dict:
    ks = jax.random.split(rng, 6)
    p: Dict[str, Any] = {"norm1": L.init_norm(cfg)}
    if mixer_kind == "attention":
        p["mixer"] = attn.init_attention(cfg, ks[0])
    elif mixer_kind == "mamba":
        p["mixer"] = ssm_mod.init_mamba(cfg, ks[0])
    elif mixer_kind == "mlstm":
        p["mixer"] = xlstm_mod.init_mlstm(cfg, ks[0])
    elif mixer_kind == "slstm":
        p["mixer"] = xlstm_mod.init_slstm(cfg, ks[0])
    else:
        raise ValueError(mixer_kind)
    if cross:
        p["norm_x"] = L.init_norm(cfg)
        p["xattn"] = attn.init_attention(cfg, ks[1])
    if mlp_kind == "dense":
        p["norm2"] = L.init_norm(cfg)
        p["mlp"] = L.init_dense_mlp(cfg, ks[2])
    elif mlp_kind == "moe":
        p["norm2"] = L.init_norm(cfg)
        p["mlp"] = moe_mod.init_moe(cfg, ks[2])
    return p


def init_block_cache(cfg, mixer_kind: str, batch: int, cap: int,
                     cross_len: int = 0) -> Dict:
    """Zeroed decode cache for one block."""
    dt = cfg.jnp_compute_dtype()
    c: Dict[str, Any] = {}
    if mixer_kind == "attention":
        hkv, dh = cfg.n_kv_heads, cfg.head_dim
        c["k"] = jnp.zeros((batch, hkv, cap, dh), dt)
        c["v"] = jnp.zeros((batch, hkv, cap, dh), dt)
    elif mixer_kind == "mamba":
        c.update(ssm_mod.init_mamba_cache(cfg, batch, dt))
    elif mixer_kind == "mlstm":
        c.update(xlstm_mod.init_mlstm_cache(cfg, batch))
    elif mixer_kind == "slstm":
        c.update(xlstm_mod.init_slstm_cache(cfg, batch))
    if cross_len:
        hkv, dh = cfg.n_kv_heads, cfg.head_dim
        c["xk"] = jnp.zeros((batch, hkv, cross_len, dh), dt)
        c["xv"] = jnp.zeros((batch, hkv, cross_len, dh), dt)
    return c


def _apply_mlp(cfg, p, mlp_kind, x):
    if mlp_kind == "none":
        return x, jnp.zeros((), jnp.float32)
    h = L.apply_norm(cfg, p["norm2"], x)
    if mlp_kind == "dense":
        return x + L.apply_dense_mlp(cfg, p["mlp"], h), jnp.zeros((), jnp.float32)
    y, aux = moe_mod.apply_moe(cfg, p["mlp"], h)
    return x + y, aux


def apply_block(
    cfg,
    p: Dict,
    kinds: Tuple[str, str],
    x: jax.Array,
    positions: jax.Array,
    causal: bool = True,
    enc_out: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict, jax.Array]:
    """Train/prefill. Returns (x, cache_contrib, aux_loss). cache_contrib has
    the same structure as init_block_cache (attention k/v filled from this
    forward; SSM states = final states) so prefill can build a decode cache."""
    mixer_kind, mlp_kind = kinds
    h = L.apply_norm(cfg, p["norm1"], x)
    cache: Dict[str, Any] = {}
    if mixer_kind == "attention":
        y, (k, v) = attn.attention_forward(cfg, p["mixer"], h, positions,
                                           causal=causal)
        cache["k"], cache["v"] = k.astype(cfg.jnp_compute_dtype()), v.astype(
            cfg.jnp_compute_dtype()
        )
        x = x + y
    elif mixer_kind == "mamba":
        y, st = ssm_mod.mamba_forward(cfg, p["mixer"], h, return_state=True)
        cache.update(st)
        x = x + y
    elif mixer_kind == "mlstm":
        y, st = xlstm_mod.mlstm_forward(cfg, p["mixer"], h, return_state=True)
        cache.update(st)
        x = x + y
    elif mixer_kind == "slstm":
        y, st = xlstm_mod.slstm_forward(cfg, p["mixer"], h, return_state=True)
        cache.update(st)
        x = x + y
    if enc_out is not None and "xattn" in p:
        hx = L.apply_norm(cfg, p["norm_x"], x)
        y, (xk, xv) = attn.attention_forward(cfg, p["xattn"], hx, positions,
                                             causal=False, kv_x=enc_out,
                                             use_rope=False)
        cache["xk"], cache["xv"] = xk.astype(cfg.jnp_compute_dtype()), xv.astype(
            cfg.jnp_compute_dtype()
        )
        x = x + y
    x, aux = _apply_mlp(cfg, p, mlp_kind, x)
    return x, cache, aux


def apply_block_decode(
    cfg,
    p: Dict,
    kinds: Tuple[str, str],
    x: jax.Array,  # [B, 1, D]
    cache: Dict,
    pos: jax.Array,
) -> Tuple[jax.Array, Dict]:
    mixer_kind, mlp_kind = kinds
    h = L.apply_norm(cfg, p["norm1"], x)
    new_cache = dict(cache)
    if mixer_kind == "attention":
        y, k, v = attn.decode_attention(cfg, p["mixer"], h, cache["k"],
                                        cache["v"], pos)
        new_cache["k"], new_cache["v"] = k, v
        x = x + y
    elif mixer_kind == "mamba":
        y, st = ssm_mod.mamba_decode(cfg, p["mixer"], h,
                                     {"conv": cache["conv"], "h": cache["h"]})
        new_cache.update(st)
        x = x + y
    elif mixer_kind == "mlstm":
        y, st = xlstm_mod.mlstm_decode(
            cfg, p["mixer"], h, {k_: cache[k_] for k_ in ("C", "n", "m")}
        )
        new_cache.update(st)
        x = x + y
    elif mixer_kind == "slstm":
        y, st = xlstm_mod.slstm_decode(
            cfg, p["mixer"], h, {k_: cache[k_] for k_ in ("c", "n", "h", "m")}
        )
        new_cache.update(st)
        x = x + y
    if "xattn" in p:
        hx = L.apply_norm(cfg, p["norm_x"], x)
        y, _, _ = attn.decode_attention(cfg, p["xattn"], hx, cache["xk"],
                                        cache["xv"], pos, cross=True)
        x = x + y
    x, _aux = _apply_mlp(cfg, p, mlp_kind, x)
    return x, new_cache


# ---------------------------------------------------------------------------
# scanned stack
# ---------------------------------------------------------------------------


def init_stack(cfg, rng, n_layers: Optional[int] = None, cross: bool = False,
               pattern: Optional[Tuple[Tuple[str, str], ...]] = None) -> Tuple:
    """Returns a tuple (one entry per period position) of param pytrees with
    leading group dim G = n_layers / period."""
    pattern = pattern or cfg.pattern()
    n_layers = n_layers or cfg.n_layers
    period = len(pattern)
    assert n_layers % period == 0, (n_layers, period)
    g = n_layers // period
    out = []
    for pp, kinds in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(rng, pp), g)
        out.append(
            jax.vmap(
                lambda k_: init_block(cfg, k_, kinds[0], kinds[1], cross=cross)
            )(keys)
        )
    return tuple(out)


def init_stack_cache(cfg, batch: int, cap: int, n_layers: Optional[int] = None,
                     cross_len: int = 0,
                     pattern: Optional[Tuple[Tuple[str, str], ...]] = None) -> Tuple:
    pattern = pattern or cfg.pattern()
    n_layers = n_layers or cfg.n_layers
    g = n_layers // len(pattern)

    def rep(tree):
        return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (g,) + a.shape), tree)

    return tuple(
        rep(init_block_cache(cfg, kinds[0], batch, cap, cross_len=cross_len))
        for kinds in pattern
    )


def apply_stack(
    cfg,
    stack_params: Tuple,
    x: jax.Array,
    positions: jax.Array,
    causal: bool = True,
    enc_out: Optional[jax.Array] = None,
    pattern: Optional[Tuple[Tuple[str, str], ...]] = None,
    remat: Optional[bool] = None,
    collect_cache: bool = False,
) -> Tuple[jax.Array, Optional[Tuple], jax.Array]:
    """Scan over layer groups. Returns (x, caches (if collected), aux_sum)."""
    pattern = pattern or cfg.pattern()
    remat = cfg.remat_stack if remat is None else remat

    def body(carry, params_g):
        xc, aux = carry
        xc = pctx.constrain_tokens(xc)
        caches = []
        for pp, kinds in enumerate(pattern):
            xc, cache, a = apply_block(cfg, params_g[pp], kinds, xc, positions,
                                       causal=causal, enc_out=enc_out)
            xc = pctx.constrain_tokens(xc)
            caches.append(cache)
            aux = aux + a
        out = tuple(caches) if collect_cache else None
        return (xc, aux), out

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    stack_params)
    return x, caches, aux


def apply_stack_decode(
    cfg,
    stack_params: Tuple,
    x: jax.Array,
    cache: Tuple,
    pos: jax.Array,
    pattern: Optional[Tuple[Tuple[str, str], ...]] = None,
) -> Tuple[jax.Array, Tuple]:
    """One decode step through the scanned stack. ``pos`` is a scalar (all
    rows at the same depth) or ``[B]`` vector (per-slot depths); it is
    closed over by the scan body and handled in ``attn.decode_attention``
    (SSM/xLSTM mixers are position-free recurrences)."""
    pattern = pattern or cfg.pattern()

    def body(xc, inp):
        params_g, cache_g = inp
        new_caches = []
        for pp, kinds in enumerate(pattern):
            xc, nc = apply_block_decode(cfg, params_g[pp], kinds, xc,
                                        cache_g[pp], pos)
            new_caches.append(nc)
        return xc, tuple(new_caches)

    x, new_cache = jax.lax.scan(body, x, (stack_params, cache))
    return x, new_cache
