"""xLSTM mixers: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, inherently sequential scan) [arXiv:2405.04517].

mLSTM uses the log-domain-stabilised chunkwise algorithm: within a chunk the
interaction is a masked (R×R) matrix; across chunks a recurrent state
(C [dh,dh], n [dh], m scalar) is carried — O(S·R) work, O(1) decode state
(this is what qualifies xlstm for ``long_500k``).

Simplifications vs the paper (DESIGN.md §7): sLSTM block's post-FFN is
omitted (d_ff=0 configs carry capacity in the mLSTM up-projection); gate
activations use the paper's stabilised exp-input/sigmoid-forget variant.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.parallel import context as pctx

_EPS = 1e-6


def _shard_map_mixer(fn, p, x, init_state, state_spec_fn):
    """Run a replicated-weight mixer manually mapped over the DP axes only
    (``axis_names`` subset; TP stays with the auto partitioner). Inside the
    mapped body the recurrent scans are *local* code, so the per-timestep
    weight-gradient all-reduces XLA inserts under SPMD (one 17 MB psum per
    sLSTM step — EXPERIMENTS §Perf) collapse into a single psum at the
    shard_map VJP boundary. The initial recurrent state is passed in (not
    created inside) so the scan carry is device-varying under check_vma.
    Falls back to plain execution when no mesh/DP context is installed or
    the batch doesn't divide."""
    mesh = pctx.mesh()
    dp = pctx.dp_axes()
    if mesh is None or dp is None:
        return fn(p, x, init_state)
    from jax.sharding import PartitionSpec as P

    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if dp_size <= 1 or x.shape[0] % dp_size:
        return fn(p, x, init_state)
    pspec = jax.tree.map(lambda _: P(), p)
    xspec = P(dp, None, None)
    sspec = state_spec_fn(P, dp)
    return jax.shard_map(
        fn, mesh=mesh, in_specs=(pspec, xspec, sspec),
        out_specs=(xspec, sspec), axis_names=set(dp), check_vma=True,
    )(p, x, init_state)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def m_dims(cfg) -> Tuple[int, int]:
    di = 2 * cfg.d_model
    return di, di // cfg.n_heads


def init_mlstm(cfg, rng) -> Dict:
    d = cfg.d_model
    di, dh = m_dims(cfg)
    h = cfg.n_heads
    dt = cfg.jnp_param_dtype()
    ks = jax.random.split(rng, 7)
    sc = d ** -0.5
    return {
        "wq": L.normal(ks[0], (d, di), sc, dt),
        "wk": L.normal(ks[1], (d, di), sc, dt),
        "wv": L.normal(ks[2], (d, di), sc, dt),
        "w_i": L.normal(ks[3], (d, h), sc, dt),
        "w_f": L.normal(ks[4], (d, h), sc, dt),
        "f_bias": jnp.full((h,), 3.0, dt),  # open forget gates at init
        "w_o": L.normal(ks[5], (d, di), sc, dt),
        "scale": jnp.ones((di,), dt),
        "out_proj": L.normal(ks[6], (di, d), di ** -0.5, dt),
    }


def _mlstm_qkv_gates(cfg, p, x):
    cd = cfg.jnp_compute_dtype()
    b, s, d = x.shape
    h = cfg.n_heads
    di, dh = m_dims(cfg)
    xf = x.astype(cd)
    q = (xf @ p["wq"].astype(cd)).reshape(b, s, h, dh).swapaxes(1, 2)
    k = (xf @ p["wk"].astype(cd)).reshape(b, s, h, dh).swapaxes(1, 2)
    v = (xf @ p["wv"].astype(cd)).reshape(b, s, h, dh).swapaxes(1, 2)
    li = (x.astype(jnp.float32) @ p["w_i"].astype(jnp.float32)).swapaxes(1, 2)
    lf = jax.nn.log_sigmoid(
        x.astype(jnp.float32) @ p["w_f"].astype(jnp.float32)
        + p["f_bias"].astype(jnp.float32)
    ).swapaxes(1, 2)  # [B,H,S]
    o = jax.nn.sigmoid(xf @ p["w_o"].astype(cd))  # [B,S,di]
    q = q.astype(jnp.float32) * (dh ** -0.5)
    return q, k.astype(jnp.float32), v.astype(jnp.float32), li, lf, o


def _mlstm_chunk(q, k, v, li, lf, carry):
    """One chunk. q,k,v [B,H,R,dh]; li,lf [B,H,R]; carry (C, n, m)."""
    C0, n0, m0 = carry
    r = q.shape[2]
    bcum = jnp.cumsum(lf, axis=2)  # [B,H,R] inclusive
    # pairwise log weights w[t,s] = b_t - b_s + li_s  (s <= t)
    logw = bcum[..., :, None] - bcum[..., None, :] + li[..., None, :]
    mask = jnp.tril(jnp.ones((r, r), bool))
    logw = jnp.where(mask, logw, -jnp.inf)
    m_intra = logw.max(-1)  # [B,H,R]
    s_inter = m0[..., None] + bcum  # [B,H,R]
    m_t = jnp.maximum(m_intra, s_inter)
    m_t = jnp.maximum(m_t, -1e30)  # guard all -inf rows

    dmat = jnp.exp(logw - m_t[..., None])  # masked rows -> 0 via -inf
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k)
    w_intra = scores * dmat
    inter_scale = jnp.exp(s_inter - m_t)  # [B,H,R]
    num = jnp.einsum("bhts,bhsd->bhtd", w_intra, v) + inter_scale[..., None] * jnp.einsum("bhtd,bhde->bhte", q, C0)
    den = w_intra.sum(-1) + inter_scale * jnp.einsum("bhtd,bhd->bht", q, n0)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

    # carry to next chunk
    b_r = bcum[..., -1]  # [B,H]
    wcar = b_r[..., None] - bcum + li  # [B,H,R]
    m_new = jnp.maximum(m0 + b_r, wcar.max(-1))
    cscale = jnp.exp(m0 + b_r - m_new)
    kw = jnp.exp(wcar - m_new[..., None])  # [B,H,R]
    C1 = cscale[..., None, None] * C0 + jnp.einsum(
        "bhs,bhsd,bhse->bhde", kw, k, v
    )
    n1 = cscale[..., None] * n0 + jnp.einsum("bhs,bhsd->bhd", kw, k)
    return h, (C1, n1, m_new)


def _mlstm_core(cfg, p: Dict, x: jax.Array, init_state: Dict):
    b, s, d = x.shape
    h_heads = cfg.n_heads
    di, dh = m_dims(cfg)
    cd = cfg.jnp_compute_dtype()
    q, k, v, li, lf, o = _mlstm_qkv_gates(cfg, p, x)

    r = min(cfg.mlstm_chunk, s)
    while s % r:
        r //= 2
    nc = s // r

    def split(t):  # [B,H,S,...] -> [nc, B,H,R,...]
        return t.reshape(t.shape[0], t.shape[1], nc, r, *t.shape[3:]).transpose(
            2, 0, 1, 3, *range(4, t.ndim + 1)
        )

    qs, ks_, vs = split(q), split(k), split(v)
    lis, lfs = split(li), split(lf)

    @jax.checkpoint  # recompute intra-chunk matrices in bwd
    def body(carry, inp):
        qi, ki, vi, li_i, lf_i = inp
        h, carry = _mlstm_chunk(qi, ki, vi, li_i, lf_i, carry)
        return carry, h

    (C1, n1, m1), hs = jax.lax.scan(
        body, (init_state["C"], init_state["n"], init_state["m"]),
        (qs, ks_, vs, lis, lfs))
    hseq = hs.transpose(1, 2, 0, 3, 4).reshape(b, h_heads, s, dh)
    hseq = hseq.swapaxes(1, 2).reshape(b, s, di)
    y = hseq.astype(cd) * p["scale"].astype(cd) * o
    out = (y @ p["out_proj"].astype(cd)).astype(x.dtype)
    return out, {"C": C1, "n": n1, "m": m1}


def mlstm_forward(cfg, p: Dict, x: jax.Array, return_state: bool = False):
    def core(p_, x_, s0_):
        return _mlstm_core(cfg, p_, x_, s0_)

    def state_specs(P, dp):
        return {"C": P(dp, None, None, None), "n": P(dp, None, None),
                "m": P(dp, None)}

    out, state = _shard_map_mixer(core, p, x, init_mlstm_cache(cfg, x.shape[0]),
                                  state_specs)
    if return_state:
        return out, state
    return out


def init_mlstm_cache(cfg, batch: int) -> Dict:
    h = cfg.n_heads
    di, dh = m_dims(cfg)
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_decode(cfg, p: Dict, x: jax.Array, cache: Dict) -> Tuple[jax.Array, Dict]:
    """x [B,1,D] — single-step mLSTM (recurrent form)."""
    q, k, v, li, lf, o = _mlstm_qkv_gates(cfg, p, x)  # S dim = 1
    h, (C1, n1, m1) = _mlstm_chunk(q, k, v, li, lf,
                                   (cache["C"], cache["n"], cache["m"]))
    b = x.shape[0]
    di, _ = m_dims(cfg)
    cd = cfg.jnp_compute_dtype()
    hseq = h.swapaxes(1, 2).reshape(b, 1, di)
    y = hseq.astype(cd) * p["scale"].astype(cd) * o
    out = (y @ p["out_proj"].astype(cd)).astype(x.dtype)
    return out, {"C": C1, "n": n1, "m": m1}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(cfg, rng) -> Dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    dt = cfg.jnp_param_dtype()
    ks = jax.random.split(rng, 3)
    return {
        "w_in": L.normal(ks[0], (d, 4 * d), d ** -0.5, dt),  # z,i,f,o preacts
        "r": L.normal(ks[1], (h, dh, 4 * dh), dh ** -0.5, dt),  # block-diag rec.
        "b": jnp.concatenate(
            [jnp.zeros((2 * d,), dt), jnp.full((d,), 3.0, dt), jnp.zeros((d,), dt)]
        ),
        "out_proj": L.normal(ks[2], (d, d), d ** -0.5, dt),
    }


def _slstm_step(cfg, p, state, xw):
    """state: (c, n, h, m) each [B, D]; xw [B, 4D] input preactivation."""
    c, n, h, m = state
    b, d = c.shape
    nh = cfg.n_heads
    dh = d // nh
    rec = jnp.einsum(
        "bhd,hde->bhe", h.reshape(b, nh, dh).astype(jnp.float32),
        p["r"].astype(jnp.float32),
    ).reshape(b, 4 * d)
    pre = xw.astype(jnp.float32) + rec + p["b"].astype(jnp.float32)
    zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(zt)
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c_new = f_p * c + i_p * z
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, _EPS)
    return (c_new, n_new, h_new, m_new), h_new


def _slstm_core(cfg, p: Dict, x: jax.Array, init_state: Dict):
    b, s, d = x.shape
    cd = cfg.jnp_compute_dtype()
    xw = (x.astype(cd) @ p["w_in"].astype(cd)).swapaxes(0, 1)  # [S, B, 4D]

    def body(state, xw_t):
        return _slstm_step(cfg, p, state, xw_t)

    state0 = (init_state["c"], init_state["n"], init_state["h"],
              init_state["m"])
    (c1, n1, h1, m1), hs = jax.lax.scan(body, state0, xw)
    y = hs.swapaxes(0, 1).astype(cd)  # [B, S, D]
    out = (y @ p["out_proj"].astype(cd)).astype(x.dtype)
    return out, {"c": c1, "n": n1, "h": h1, "m": m1}


def slstm_forward(cfg, p: Dict, x: jax.Array, return_state: bool = False):
    def core(p_, x_, s0_):
        return _slstm_core(cfg, p_, x_, s0_)

    def state_specs(P, dp):
        return {k: P(dp, None) for k in ("c", "n", "h", "m")}

    out, state = _shard_map_mixer(core, p, x, init_slstm_cache(cfg, x.shape[0]),
                                  state_specs)
    if return_state:
        return out, state
    return out


def init_slstm_cache(cfg, batch: int) -> Dict:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), -1e30, jnp.float32)}


def slstm_decode(cfg, p: Dict, x: jax.Array, cache: Dict) -> Tuple[jax.Array, Dict]:
    cd = cfg.jnp_compute_dtype()
    xw = x[:, 0].astype(cd) @ p["w_in"].astype(cd)
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    (c, n, h, m), h_out = _slstm_step(cfg, p, state, xw)
    y = (h_out.astype(cd) @ p["out_proj"].astype(cd)).astype(x.dtype)
    return y[:, None], {"c": c, "n": n, "h": h, "m": m}
