"""AdamW with dtype-configurable / int8-block-quantised moments.

At 400B params, fp32 (m, v) alone is 3.2 TB — over the 256×16 GiB single-pod
HBM budget once params+activations join. The state dtype is therefore a
first-class config: "float32", "bfloat16", or "int8" (block-wise quantised
with per-block f32 scales, 128-wide blocks along the last axis — the
distributed-optimization trick from the 8-bit-optimizer line of work).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

_BLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"  # float32 | bfloat16 | int8


# ---------------------------------------------------------------------------
# int8 block quantisation (shape-preserving: q keeps the tensor's shape, the
# f32 scales get a trailing block dim — so the tensor's sharding rules apply
# verbatim to the quantised state, and encode/decode fuse shard-locally)
# ---------------------------------------------------------------------------


def quantizable(x) -> bool:
    return x.ndim >= 1 and x.shape[-1] % _BLOCK == 0


def quantize_i8(x: jax.Array) -> Dict[str, jax.Array]:
    assert quantizable(x), x.shape
    blocks = x.astype(jnp.float32).reshape(*x.shape[:-1], -1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0  # [..., L/128]
    q = jnp.round(
        blocks / jnp.maximum(scale[..., None], 1e-12)
    ).astype(jnp.int8)
    return {"q": q.reshape(x.shape), "scale": scale}


def dequantize_i8(st: Dict[str, jax.Array], shape=None,
                  dtype=jnp.float32) -> jax.Array:
    q = st["q"]
    blocks = q.astype(jnp.float32).reshape(*q.shape[:-1], -1, _BLOCK)
    x = blocks * st["scale"][..., None]
    return x.reshape(q.shape).astype(dtype)


# ---------------------------------------------------------------------------
# state handling
# ---------------------------------------------------------------------------


def _encode_moment(x: jax.Array, dtype: str):
    if dtype == "int8":
        if quantizable(x) and x.size >= 65536:
            return quantize_i8(x)
        return x.astype(jnp.bfloat16)  # small / misaligned leaves
    return x.astype(getattr(jnp, dtype))


def _decode_moment(st, shape, dtype: str) -> jax.Array:
    if isinstance(st, dict) and "q" in st:
        return dequantize_i8(st)
    return st.astype(jnp.float32)


def init_state(cfg: AdamWConfig, params) -> Dict[str, Any]:
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _encode_moment(z, cfg.state_dtype)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zero_like, params),
        "v": jax.tree.map(zero_like, params),
    }


def apply_updates(
    cfg: AdamWConfig,
    params,
    grads,
    state: Dict[str, Any],
    lr_scale: jax.Array | float = 1.0,
):
    """Returns (new_params, new_state, metrics). Global-norm clip + AdamW."""
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    is_moment_leaf = lambda x: isinstance(x, dict) and "q" in x  # noqa: E731

    def upd(p, g, m_st, v_st):
        g = g.astype(jnp.float32) * clip
        m = _decode_moment(m_st, p.shape, cfg.state_dtype)
        v = _decode_moment(v_st, p.shape, cfg.state_dtype)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return newp, _encode_moment(m, cfg.state_dtype), _encode_moment(
            v, cfg.state_dtype
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return (
        new_params,
        {"step": step, "m": new_m, "v": new_v},
        {"grad_norm": gnorm},
    )
