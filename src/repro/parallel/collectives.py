"""Distributed-optimization collectives helpers.

``int8_compress_decompress``: block-quantise gradients to int8 (+f32 block
scales) and immediately dequantise. Placed between the backward pass and the
optimizer, the data-parallel gradient reduction XLA inserts then moves ~4×
fewer mantissa bits of information (the quantisation error is what the real
int8-all-reduce would incur; on an explicit-collective runtime the psum runs
on the int8 payload itself — here the compiler sees the same numerics).
Used by ``make_train_step(grad_compression="int8")`` and benchmarked in the
§Perf collective-bound hillclimb.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import dequantize_i8, quantizable, quantize_i8


def int8_compress_decompress(grads):
    def roundtrip(g):
        if not quantizable(g):  # tiny/misaligned leaves: keep exact
            return g
        return dequantize_i8(quantize_i8(g), dtype=g.dtype)

    return jax.tree.map(roundtrip, grads)


def psum_int8(x, axis_name):
    """Explicit quantised all-reduce for shard_map code paths: quantise,
    reduce the dequantised (block-scaled) payload, keep input dtype. On an
    explicit-collective runtime the int8 payload itself is what moves."""
    if not quantizable(x):
        return jax.lax.psum(x, axis_name)
    deq = dequantize_i8(quantize_i8(x))
    return jax.lax.psum(deq, axis_name).astype(x.dtype)
