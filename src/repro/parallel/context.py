"""Activation-sharding context.

Model code is mesh-agnostic; the launcher (dryrun/train/serve) installs the
data-parallel axes here and layers call ``constrain_tokens`` /
``constrain_seq`` at block boundaries. With no context installed (unit tests,
single-device runs) these are no-ops.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_DP_AXES: Optional[Tuple[str, ...]] = None
_TP_AXIS: Optional[str] = None
_TP_SIZE: int = 1
_SP_SEQ: bool = False  # sequence-parallel activations between blocks
_MESH = None  # concrete mesh for shard_map code paths
_MOE_PIN = False  # pin MoE dispatch shardings (refuted optimisation — §Perf)


def install(dp_axes: Tuple[str, ...], tp_axis: str = "model",
            tp_size: int = 1, sp_seq: bool = False, mesh=None,
            moe_pin: bool = False) -> None:
    global _DP_AXES, _TP_AXIS, _TP_SIZE, _SP_SEQ, _MESH, _MOE_PIN
    _DP_AXES, _TP_AXIS, _TP_SIZE, _SP_SEQ, _MESH, _MOE_PIN = (
        tuple(dp_axes), tp_axis, tp_size, sp_seq, mesh, moe_pin
    )


def clear() -> None:
    global _DP_AXES, _TP_AXIS, _TP_SIZE, _SP_SEQ, _MESH, _MOE_PIN
    _DP_AXES, _TP_AXIS, _TP_SIZE, _SP_SEQ, _MESH, _MOE_PIN = (
        None, None, 1, False, None, False
    )


def moe_pin() -> bool:
    return _MOE_PIN


def mesh():
    return _MESH


def dp_axes():
    return _DP_AXES


@contextlib.contextmanager
def activation_sharding(dp_axes: Tuple[str, ...], tp_axis: str = "model",
                        tp_size: int = 1, sp_seq: bool = False):
    prev = (_DP_AXES, _TP_AXIS, _TP_SIZE, _SP_SEQ)
    install(dp_axes, tp_axis, tp_size, sp_seq)
    try:
        yield
    finally:
        install(*prev) if prev[0] is not None else clear()


def constrain_dims(x: jax.Array, dims: Tuple) -> jax.Array:
    """Generic constraint: ``dims`` entries are 'dp', 'tp', or None per
    leading axis (trailing axes unconstrained). Divisibility-guarded; no-op
    without an installed context (unit tests, single device)."""
    if _DP_AXES is None:
        return x
    spec = []
    for i, d in enumerate(dims[: x.ndim]):
        if d == "dp":
            spec.append(_DP_AXES)
        elif d == "tp":
            spec.append(_TP_AXIS if x.shape[i] % max(1, _TP_SIZE) == 0
                        else None)
        else:
            spec.append(None)
    spec += [None] * (x.ndim - len(spec))
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, TypeError):
        return x


def constrain_tokens(x: jax.Array) -> jax.Array:
    """[B, S, D] (or [B, S]) activations: batch over DP; with SP enabled the
    seq dim additionally shards over TP (Megatron-style sequence parallelism
    for the norm/residual regions — XLA turns the boundary into the standard
    all-gather-at-QKV / reduce-scatter-after-Wo pair)."""
    if _DP_AXES is None:
        return x
    if x.ndim == 3:
        seq_ax = (
            _TP_AXIS if (_SP_SEQ and x.shape[1] % max(1, _TP_SIZE) == 0
                         and x.shape[1] >= _TP_SIZE) else None
        )
        spec = P(_DP_AXES, seq_ax, None)
    elif x.ndim == 2:
        spec = P(_DP_AXES, None)
    else:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, TypeError):
        return x  # no mesh context — leave to propagation
