"""GPipe-style pipeline parallelism over the ``pod`` mesh axis.

At 2+ pods, inter-pod ICI is the scarcest link — pipelining the layer stack
across pods exchanges only the [micro_batch, seq, d_model] activations at
stage boundaries (vs FSDP's per-layer weight gathers crossing pods).

Implementation: ``shard_map`` over the ``pod`` axis; each pod holds its
stage's parameter slice (leading stage dim sharded over ``pod``), and a
``lax.scan`` over ``n_micro + n_stages - 1`` clock ticks runs the classic
GPipe schedule: at tick t, stage s processes microbatch ``t - s`` (bubble
ticks compute-and-discard); activations move stage→stage+1 with
``jax.lax.ppermute``. The returned structure composes with the rest of the
framework (the stage function is any ``f(stage_params, x) -> x``).

This is the forward pipeline (inference / activation-forward for PP+grad
via jax.grad — scan+ppermute are differentiable, giving the standard GPipe
fill/drain backward automatically). Tested for numeric equivalence against
sequential execution on a (pod=2, data, model) mini-mesh
(tests/test_pipeline.py).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(
    stage_fn: Callable,
    stage_params,
    x: jax.Array,  # [n_micro, micro_batch, ...] microbatched input
    *,
    mesh,
    axis: str = "pod",
    param_specs=None,
    x_spec: P = None,
) -> jax.Array:
    """Run ``stage_fn`` as a pipeline over ``axis``. ``stage_params`` leaves
    must have a leading stage dim equal to the axis size; ``x`` is
    microbatched on its leading dim. Returns outputs with x's structure."""
    n_stages = int(mesh.shape[axis])
    n_micro = x.shape[0]
    assert n_micro >= 1
    if param_specs is None:
        param_specs = jax.tree.map(lambda _: P(axis), stage_params)
    if x_spec is None:
        x_spec = P()  # microbatches replicated across the pipeline axis

    def staged(params, xs):
        # inside shard_map: params leaves have leading dim 1 (this stage)
        params = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)
        ticks = n_micro + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            inflight, outputs = carry
            # stage 0 ingests microbatch t (when valid); others take the
            # activation permuted in from the previous stage at tick end
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            feed = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0, keepdims=False)
            x_in = jnp.where(stage == 0, feed, inflight)
            y = stage_fn(params, x_in)
            # last stage commits microbatch (t - n_stages + 1) when valid
            out_idx = t - (n_stages - 1)
            valid = jnp.logical_and(stage == n_stages - 1, out_idx >= 0)
            outputs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(out_idx, 0, n_micro - 1), 0
                ),
                lambda o: o,
                outputs,
            )
            nxt = jax.lax.ppermute(y, axis, perm)
            return (nxt, outputs), None

        zeros = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        (_, outputs), _ = jax.lax.scan(
            tick, (zeros, outs0), jnp.arange(ticks)
        )
        # only the last stage holds real outputs; replicate across the
        # pipeline axis (masked psum = broadcast from the last stage)
        outputs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outputs, jnp.zeros_like(outputs)),
            axis,
        )
        return outputs

    if hasattr(jax, "shard_map"):
        mapped = jax.shard_map(
            staged,
            mesh=mesh,
            in_specs=(param_specs, x_spec),
            out_specs=x_spec,
            axis_names={axis},
            check_vma=False,
        )
    else:  # jax < 0.5: experimental API, fully manual (partial-manual via
        # ``auto=`` trips SPMD PartitionId there; unmentioned axes simply
        # see replicated data, and the pipeline body only collects on
        # ``axis``, so full manual is equivalent for this use)
        from jax.experimental.shard_map import shard_map

        mapped = shard_map(
            staged,
            mesh=mesh,
            in_specs=(param_specs, x_spec),
            out_specs=x_spec,
            check_rep=False,
        )
    return mapped(stage_params, x)
