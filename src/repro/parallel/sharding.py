"""Divisibility-aware sharding rules: DP / FSDP / TP / EP / SP.

Design (DESIGN.md §5):
  * batch dims           → DP over ('pod','data')
  * TP feature dims      → 'model' (attention heads / d_ff / vocab / d_inner)
  * FSDP storage dim     → 'data' (weights gathered per scanned layer group)
  * MoE expert dim       → 'model' (EP; 128 experts / 16 = 8 per shard)
  * KV-cache             → heads over 'model' when divisible, else the
                           *sequence* dim over 'model' (flash-decode SP —
                           covers GQA kv_heads < 16 and long_500k)

Every rule is guarded: a dim is sharded only if its size divides the mesh
axes product; otherwise that dim falls back to replicated (internvl's 14
heads, whisper's 51866 vocab). Rules are written against *trailing* dims so
the scanned stack's leading G (group) dim and any moment/quantisation
wrappers need no special-casing.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes

FSDP = "data"
TP = "model"

# trailing-dim specs by (parent-context, leaf-name); "DP" resolved at bind
# time; entries may be shorter than leaf.ndim (left-padded with None).
_PARAM_RULES: Dict[str, Tuple] = {
    # embedding / head
    "tok": (TP, FSDP),
    "head": (FSDP, TP),
    "enc_pos": (None, FSDP),
    "vis_proj": (FSDP, TP),
    # attention
    "wq": (FSDP, TP),
    "wk": (FSDP, TP),
    "wv": (FSDP, TP),
    "wo": (TP, FSDP),
    "bq": (TP,),
    "bk": (TP,),
    "bv": (TP,),
    # dense mlp (trailing 2 dims) — moe variants matched by ndim below
    "w1": (FSDP, TP),
    "w3": (FSDP, TP),
    "w2": (TP, FSDP),
    "router": (FSDP, None),
    # mamba
    "in_proj": (FSDP, TP),
    "out_proj": (TP, FSDP),
    "conv_w": (None, TP),
    "conv_b": (TP,),
    "w_bc": (TP, None),
    "w_dt": (TP, None),
    "dt_proj": (None, TP),
    "dt_bias": (TP,),
    "A_log": (TP, None),
    "D": (TP,),
    # mlstm / slstm
    "w_i": (FSDP, TP),
    "w_f": (FSDP, TP),
    "f_bias": (TP,),
    "w_o": (FSDP, TP),
    "scale": (TP,),
    "w_in": (FSDP, TP),
    "r": (None, None, TP),
    "b": (TP,),  # slstm bias; norm 'b' overridden by norm context
}

_MOE_3D = {"w1": (TP, FSDP, None), "w3": (TP, FSDP, None), "w2": (TP, None, FSDP)}

_NORM_PARENTS = ("norm1", "norm2", "norm_x", "norm_f", "enc_norm_f", "norm")


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(f"[{k.idx}]")
        else:
            names.append(str(k))
    return tuple(names)


def _guard(spec: Tuple, shape: Tuple[int, ...], mesh) -> P:
    """Left-pad to ndim and drop axes that don't divide the dim."""
    spec = (None,) * (len(shape) - len(spec)) + tuple(spec)
    spec = spec[-len(shape):] if shape else ()
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = 1
        for a in axes:
            if a not in mesh.axis_names:
                size = 0
                break
            size *= mesh.shape[a]
        out.append(ax if size and dim % size == 0 else None)
    return P(*out)


def head_aware_overrides(cfg, mesh) -> Dict[str, Tuple]:
    """Config-aware rule overrides (Megatron-style): when head counts don't
    divide the TP axis, a flattened (heads·dh) shard would split head_dim —
    turning every attention score einsum into a per-chunk all-reduce (the
    728 GB/step pathology in EXPERIMENTS §Perf). Instead:

      * kv_heads % tp != 0  → replicate K/V projections (KV is small; this
        is what Megatron does for GQA with kv < tp);
      * heads % tp != 0     → replicate Q/O too; attention parallelism then
        comes from sequence sharding (SP) instead of head sharding;
      * mLSTM/sLSTM with heads % tp != 0 → replicate mixers' feature dims
        (dh-contracting einsums otherwise psum per chunk/timestep).
    """
    tp = mesh.shape.get(TP, 1)
    ov: Dict[str, Tuple] = {}
    if cfg is None or tp == 1:
        return ov
    if cfg.n_kv_heads % tp != 0:
        ov.update({"wk": (FSDP, None), "wv": (FSDP, None),
                   "bk": (None,), "bv": (None,)})
    if cfg.n_heads % tp != 0:
        ov.update({"wq": (FSDP, None), "bq": (None,), "wo": (None, FSDP)})
        if cfg.default_mixer in ("mlstm",) or cfg.slstm_every:
            ov.update({
                "w_i": (FSDP, None), "w_f": (FSDP, None), "f_bias": (None,),
                "w_o": (FSDP, None), "scale": (None,),
                "out_proj": (None, FSDP),
                "w_in": (FSDP, None), "r": (None, None, None), "b": (None,),
            })
    return ov


def param_spec(path, leaf, mesh, overrides: Optional[Dict[str, Tuple]] = None) -> P:
    names = _path_names(path)
    name = names[-1]
    parents = names[:-1]
    if any(p in _NORM_PARENTS for p in parents[-2:]):
        return P()
    rule: Optional[Tuple] = None
    # MoE expert weights are the only rank-4 w1/w2/w3 leaves ([G, E, D, F]);
    # dense (incl. shared-expert) stacks are rank 3 ([G, D, F]).
    if name in _MOE_3D and getattr(leaf, "ndim", 0) == 4 and "shared" not in parents:
        rule = _MOE_3D[name]
    if rule is None and overrides:
        rule = overrides.get(name)
    if rule is None:
        rule = _PARAM_RULES.get(name)
    if rule is None:
        return P()
    return _guard(rule, leaf.shape, mesh)


def params_sharding(params_shape, mesh, cfg=None):
    """Pytree of NamedShardings matching an (abstract) param tree."""
    ov = head_aware_overrides(cfg, mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf, mesh, overrides=ov)
        ),
        params_shape,
    )


def opt_state_sharding(opt_shape, params_shape, mesh, cfg=None):
    """Moments mirror params. int8-quantised moments ({'q','scale'}) are
    shape-preserving: q takes the param's spec verbatim; the scale drops the
    last (blocked) dim's axis; step is replicated."""
    ov = head_aware_overrides(cfg, mesh)
    pspec = jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf, mesh, overrides=ov),
        params_shape,
    )

    def moment_spec(ps_tree, m_tree):
        def one(ps, m_leaf_or_dict):
            if isinstance(m_leaf_or_dict, dict):  # int8 {'q','scale'}
                sc_spec = P(*(tuple(ps)[:-1] + (None,))) if len(tuple(ps)) else P()
                return {
                    "q": NamedSharding(
                        mesh, _guard(tuple(ps), m_leaf_or_dict["q"].shape, mesh)
                    ),
                    "scale": NamedSharding(
                        mesh,
                        _guard(tuple(sc_spec), m_leaf_or_dict["scale"].shape,
                               mesh),
                    ),
                }
            return NamedSharding(mesh, ps)
        return jax.tree.map(one, ps_tree, m_tree,
                            is_leaf=lambda x: isinstance(x, dict) and "q" in x)

    return {
        "step": NamedSharding(mesh, P()),
        "m": moment_spec(pspec, opt_shape["m"]),
        "v": moment_spec(pspec, opt_shape["v"]),
    }


# ---------------------------------------------------------------------------
# batch / cache
# ---------------------------------------------------------------------------


def batch_sharding(batch_shape, mesh):
    dp = dp_axes(mesh)

    def spec(path, leaf):
        return NamedSharding(mesh, _guard((dp,) + (None,) * (leaf.ndim - 1),
                                          leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec, batch_shape)


_CACHE_RULES: Dict[str, Tuple] = {
    # name -> trailing dims spec AFTER [G, B] prefix; B handled separately
    "k": ("HEADS_OR_SEQ",),
    "v": ("HEADS_OR_SEQ",),
    "xk": ("HEADS_OR_SEQ",),
    "xv": ("HEADS_OR_SEQ",),
    "conv": (None, TP),  # [B, K-1, di]
    "h": "H_BY_RANK",  # mamba h [B, di, N] | slstm h [B, D]
    "C": (None, TP, None),  # [B, H, dh, dh] -> shard first dh
    "n": "N_BY_RANK",  # mlstm [B,H,dh] | slstm [B,D]
    "m": "M_BY_RANK",  # mlstm [B,H] | slstm [B,D]
    "c": (TP,),  # slstm [B, D]
}


def cache_spec(path, leaf, mesh) -> P:
    """Cache leaves are [G, B, ...]."""
    names = _path_names(path)
    name = names[-1]
    dp = dp_axes(mesh)
    shape = leaf.shape
    tp_size = mesh.shape[TP]

    if name in ("k", "v", "xk", "xv"):
        # [G, B, Hkv, cap, dh]
        g, b, hkv, cap, dh = shape
        if hkv % tp_size == 0:
            spec = (None, dp, TP, None, None)
        elif cap % tp_size == 0:
            spec = (None, dp, None, TP, None)  # sequence-sharded (SP decode)
        else:
            spec = (None, dp, None, None, None)
        return _guard(spec, shape, mesh)
    if name == "conv":
        return _guard((None, dp, None, TP), shape, mesh)
    if name == "h":
        if len(shape) == 4:  # mamba [G, B, di, N]
            return _guard((None, dp, TP, None), shape, mesh)
        return _guard((None, dp, TP), shape, mesh)  # slstm [G, B, D]
    if name == "C":
        return _guard((None, dp, None, TP, None), shape, mesh)
    if name == "n":
        if len(shape) == 4:  # mlstm [G, B, H, dh]
            return _guard((None, dp, None, TP), shape, mesh)
        return _guard((None, dp, TP), shape, mesh)
    if name == "m":
        if len(shape) == 3:  # mlstm [G, B, H]
            return _guard((None, dp, None), shape, mesh)
        return _guard((None, dp, TP), shape, mesh)
    if name == "c":
        return _guard((None, dp, TP), shape, mesh)
    return _guard((None, dp), shape, mesh)


def cache_sharding(cache_shape, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, cache_spec(path, leaf, mesh)),
        cache_shape,
    )


def replicated(mesh):
    return NamedSharding(mesh, P())
