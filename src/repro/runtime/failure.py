"""Failure injection + restart policy for the training loop.

``FailureInjector`` raises ``InjectedFailure`` at configured steps (tests and
chaos drills); ``RestartPolicy`` drives the train loop's recover-from-latest-
checkpoint behaviour with bounded retries — the single-process analogue of a
cluster scheduler rescheduling a died pod.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Optional, Set


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: Set[int] = dataclasses.field(default_factory=set)
    fail_during_save_at: Set[int] = dataclasses.field(default_factory=set)
    _fired: Set[int] = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int, phase: str = "step") -> None:
        target = (
            self.fail_during_save_at if phase == "save" else self.fail_at_steps
        )
        if step in target and (step, phase) not in self._fired:
            self._fired.add((step, phase))
            raise InjectedFailure(f"injected failure at step {step} ({phase})")


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 3
    backoff_seconds: float = 0.0
    restarts: int = 0

    def should_restart(self, exc: Exception) -> bool:
        if self.restarts >= self.max_restarts:
            return False
        self.restarts += 1
        if self.backoff_seconds:
            time.sleep(self.backoff_seconds * self.restarts)
        return True
