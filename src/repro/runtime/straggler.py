"""Straggler detection: rolling step-time stats + mitigation hooks.

At 1000+ nodes the common failure mode is not death but slowness (one host's
HBM throttling, a flaky NIC). The monitor keeps a rolling median of step
times; a step exceeding ``threshold × median`` raises a flag with a suggested
mitigation:

  * ``rebalance_data``  — input-bound (loader fetch time dominates)
  * ``exclude_and_remesh`` — persistent compute slowness (the elastic path:
     checkpoint → shrink mesh → restore, see checkpoint/elastic.py)
  * ``transient``       — one-off; log only

On this single-host container the signals are simulated in tests via an
injected sleep; the policy logic is what's exercised.
"""
from __future__ import annotations

import dataclasses
import os
import statistics
import time
from typing import Deque, List, Optional
from collections import deque


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_seconds: float
    median_seconds: float
    mitigation: str


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, window: int = 32,
                 persistent_after: int = 3, min_seconds: float = 0.05):
        self.threshold = threshold
        self.window: Deque[float] = deque(maxlen=window)
        self.persistent_after = persistent_after
        self.min_seconds = min_seconds  # ignore micro-jitter on tiny steps
        self._consecutive_slow = 0
        self.events: List[StragglerEvent] = []

    def record(self, step: int, step_seconds: float,
               fetch_seconds: float = 0.0) -> Optional[StragglerEvent]:
        if len(self.window) >= 4:
            med = statistics.median(self.window)
            if step_seconds > max(self.threshold * med, self.min_seconds):
                self._consecutive_slow += 1
                if fetch_seconds > 0.5 * step_seconds:
                    mitigation = "rebalance_data"
                elif self._consecutive_slow >= self.persistent_after:
                    mitigation = "exclude_and_remesh"
                else:
                    mitigation = "transient"
                ev = StragglerEvent(step, step_seconds, med, mitigation)
                self.events.append(ev)
                self.window.append(step_seconds)
                return ev
        self._consecutive_slow = 0
        self.window.append(step_seconds)
        return None


class Heartbeat:
    """Liveness file the cluster supervisor polls (touch per step)."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def beat(self, step: int) -> None:
        with open(self.path, "w") as f:
            f.write(f"{step} {time.time()}")
