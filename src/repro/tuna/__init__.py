"""repro.tuna — persistent schedule database + distributed tuning fleet.

The MITuna-style layer over the static tuner: ``db`` persists ``cm1``
schedule records keyed by (op signature, target, cost-model version);
``orchestrator`` fans tuning jobs over a process pool; ``fleet`` shards the
job matrix across hosts and reconciles per-shard stores; ``cache`` compiles
the store into an immutable serving-time snapshot; ``cli`` drives all of it
(``python -m repro.tuna``). ``core.tuner`` consults the snapshot and the DB
transparently — see ``tuner.set_default_db`` / ``set_default_cache`` and
the ``REPRO_TUNA_DB`` / ``REPRO_TUNA_CACHE`` env vars.

Only ``db`` and ``cache`` are imported eagerly (``orchestrator``/``fleet``
pull in ``repro.core``; keeping this module light avoids an import cycle).
"""
from repro.tuna.cache import ScheduleCache
from repro.tuna.db import ScheduleDatabase, ScheduleRecord, SCHEMA

__all__ = ["ScheduleCache", "ScheduleDatabase", "ScheduleRecord", "SCHEMA"]
