"""repro.tuna — persistent schedule database + distributed tuning fleet.

The MITuna-style layer over the static tuner: ``db`` persists ``cm1``
schedule records keyed by (op signature, target, cost-model version);
``orchestrator`` fans tuning jobs over a process pool; ``fleet`` shards the
job matrix across hosts and reconciles per-shard stores; ``transport``
moves shard stores and snapshots between hosts over manifest-verified
channels (no shared filesystem required); ``cache`` compiles the store
into an immutable serving-time snapshot and manages its lifecycle
(``SnapshotManager``: versioned names, a ``latest`` pointer, publish);
``controller`` runs the whole fleet as a daemon — lease-tracked shard
dispatch, crash healing, sync + verify, snapshot republish, and an HTTP
schedule/health/metrics API (``python -m repro.tuna controller``);
``cli`` drives all of it (``python -m repro.tuna``). ``core.tuner``
consults the snapshot and the DB transparently and hot-reloads republished
snapshots via ``refresh_default_cache`` — see ``tuner.set_default_db`` /
``set_default_cache`` and the ``REPRO_TUNA_DB`` / ``REPRO_TUNA_CACHE`` env
vars.

Only ``db``, ``cache``, and ``transport`` are imported eagerly
(``orchestrator``/``fleet`` pull in ``repro.core``; keeping this module
light avoids an import cycle).
"""
from repro.tuna.cache import (
    ScheduleCache,
    SnapshotManager,
    StaleSnapshotError,
)
from repro.tuna.db import ScheduleDatabase, ScheduleRecord, SCHEMA
from repro.tuna.transport import (
    LocalDirTransport,
    MemoryTransport,
    Transport,
    resolve_transport,
)

__all__ = [
    "LocalDirTransport",
    "MemoryTransport",
    "ScheduleCache",
    "ScheduleDatabase",
    "ScheduleRecord",
    "SCHEMA",
    "SnapshotManager",
    "StaleSnapshotError",
    "Transport",
    "resolve_transport",
]
