"""repro.tuna — persistent schedule database + parallel tuning service.

The MITuna-style layer over the static tuner: ``db`` persists ``cm1``
schedule records keyed by (op signature, target, cost-model version);
``orchestrator`` fans tuning jobs over a process pool; ``cli`` drives both
(``python -m repro.tuna``). ``core.tuner`` consults the DB transparently —
see ``tuner.set_default_db`` / the ``REPRO_TUNA_DB`` env var.

Only ``db`` is imported eagerly (``core.tuner`` lazily imports it; keeping
this module light avoids an import cycle with ``repro.core``).
"""
from repro.tuna.db import ScheduleDatabase, ScheduleRecord, SCHEMA

__all__ = ["ScheduleDatabase", "ScheduleRecord", "SCHEMA"]
