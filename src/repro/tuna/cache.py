"""Serving-time schedule cache — an immutable snapshot of the store.

The JSONL store is optimised for *writes*: append-only log, cross-process
locks, best-record index rebuilt on every open. The serving hot path wants
the opposite trade — pure reads at request rate, no locks, no log scans —
the same offline/online split as TPU learned-cost-model serving: tune
offline into the store, then compile the best-record set into a flat
artifact and serve lookups from that. ``ScheduleCache`` is the artifact:
built by ``python -m repro.tuna snapshot`` (or ``ScheduleCache.build``),
loaded once, immutable thereafter, so ``best()`` is a single dict probe
with no lock acquisition — safe to share across serving threads.

Snapshot files are one JSON object (schema ``tuna-snapshot-v1``) carrying a
sha1 digest over the record payload; ``load`` verifies it, so a torn copy
from a fleet rsync fails loudly instead of silently serving half a store.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Dict, List, Optional, Sequence, Union

from repro.core.cost_model import COST_MODEL_VERSION
from repro.tuna.db import (
    Key,
    ScheduleDatabase,
    ScheduleRecord,
    query_index,
    record_beats,
)

SNAPSHOT_SCHEMA = "tuna-snapshot-v1"


def _payload(records: Sequence[Dict]) -> str:
    # canonical serialization shared by save() and load(): json round-trips
    # floats via shortest-repr, so dump(load(dump(x))) == dump(x)
    return json.dumps(list(records), sort_keys=True, default=float)


class ScheduleCache:
    """Immutable best-record index with O(1) lock-free lookups."""

    immutable = True  # write paths (tuner write-backs) check this flag

    def __init__(self, records: Sequence[ScheduleRecord],
                 source: str = "<memory>"):
        best: Dict[Key, ScheduleRecord] = {}
        for rec in records:
            cur = best.get(rec.key)
            if cur is None or record_beats(rec, cur):
                best[rec.key] = rec
        self._best = best
        self.source = source
        self.hits = 0    # serving stats: plain ints, never locked (exact
        self.misses = 0  # under the GIL, approximate under free threading)

    # -- build / persist -------------------------------------------------

    @classmethod
    def from_db(cls, db: ScheduleDatabase) -> "ScheduleCache":
        return cls(db.records(), source=db.path or "<memory>")

    @classmethod
    def build(cls, db: Union[str, os.PathLike, ScheduleDatabase],
              out_path: str) -> "ScheduleCache":
        """Compile a store (path or instance) into a snapshot file."""
        if not isinstance(db, ScheduleDatabase):
            db = ScheduleDatabase(os.fspath(db))
        cache = cls.from_db(db)
        cache.save(out_path)
        return cache

    def save(self, out_path: str) -> int:
        """Write the snapshot (atomic temp-file + replace); returns the
        record count."""
        records = [dataclasses.asdict(r) for r in self.records()]
        payload = _payload(records)
        obj = {
            "schema": SNAPSHOT_SCHEMA,
            "cost_model_version": COST_MODEL_VERSION,
            "source": self.source,
            "count": len(records),
            "sha1": hashlib.sha1(payload.encode()).hexdigest(),
            "records": records,
        }
        d = os.path.dirname(out_path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".snapshot.tmp")
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(obj, f, sort_keys=True, default=float)
                f.write("\n")
            os.replace(tmp, out_path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return len(records)

    @classmethod
    def load(cls, path: str) -> "ScheduleCache":
        """Load + verify a snapshot; raises ValueError on schema mismatch
        or digest corruption."""
        with open(path, "r", encoding="utf-8") as f:
            obj = json.load(f)
        if obj.get("schema") != SNAPSHOT_SCHEMA:
            raise ValueError(
                f"{path}: not a schedule snapshot "
                f"(schema={obj.get('schema')!r}, want {SNAPSHOT_SCHEMA!r})")
        digest = hashlib.sha1(_payload(obj["records"]).encode()).hexdigest()
        if digest != obj.get("sha1"):
            raise ValueError(
                f"{path}: snapshot digest mismatch (corrupt or torn copy); "
                f"rebuild with `python -m repro.tuna snapshot`")
        records = [ScheduleRecord.from_dict(r) for r in obj["records"]]
        return cls(records, source=obj.get("source", path))

    # -- reads (the serving hot path) ------------------------------------

    def best(self, op: str, target: str,
             version: str = COST_MODEL_VERSION) -> Optional[ScheduleRecord]:
        rec = self._best.get((op, target, version))
        if rec is None:
            self.misses += 1
        else:
            self.hits += 1
        return rec

    def query(self, op: Optional[str] = None, target: Optional[str] = None,
              version: Optional[str] = None) -> List[ScheduleRecord]:
        """Same filter semantics as ``ScheduleDatabase.query`` (shared
        implementation, so the stores cannot diverge)."""
        return query_index(self._best, op=op, target=target, version=version)

    def records(self) -> List[ScheduleRecord]:
        return [self._best[k] for k in sorted(self._best)]

    def add(self, *args, **kwargs):
        raise TypeError(
            "ScheduleCache is an immutable snapshot; write to the "
            "ScheduleDatabase and rebuild (`python -m repro.tuna snapshot`)")

    def __len__(self) -> int:
        return len(self._best)

    def __contains__(self, key: Key) -> bool:
        return key in self._best
