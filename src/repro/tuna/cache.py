"""Serving-time schedule cache — an immutable snapshot of the store.

The JSONL store is optimised for *writes*: append-only log, cross-process
locks, best-record index rebuilt on every open. The serving hot path wants
the opposite trade — pure reads at request rate, no locks, no log scans —
the same offline/online split as TPU learned-cost-model serving: tune
offline into the store, then compile the best-record set into a flat
artifact and serve lookups from that. ``ScheduleCache`` is the artifact:
built by ``python -m repro.tuna snapshot`` (or ``ScheduleCache.build``),
loaded once, immutable thereafter, so ``best()`` is a single dict probe
with no lock acquisition — safe to share across serving threads.

Snapshot files are one JSON object (schema ``tuna-snapshot-v1``) written
header-first: ``schema``/``cost_model_version``/``count``/``sha1`` come
before the record array, so ``read_snapshot_header`` can stat a snapshot's
identity from the first few KB without parsing the records. ``load``
verifies the sha1 digest (torn fleet copies fail loudly) and rejects
snapshots built under a different ``COST_MODEL_VERSION`` — the version is
part of every record key, so a stale snapshot would load cleanly and then
miss on every single lookup, silently sending serving back to full
searches (pass ``allow_stale=True`` to keep it, with a warning).

``SnapshotManager`` is the lifecycle above single files: content-addressed
snapshot names (``<prefix>.<cost-model-version>-<digest>.json``) plus an
atomically-updated ``latest`` pointer, rebuilt whenever the store content
or the cost-model version changes, and publishable over a
``repro.tuna.transport`` channel. Long-running serve processes hot-reload
through ``core.tuner.refresh_default_cache()``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import time
import warnings
from typing import Dict, List, Optional, Sequence, Union

from repro.core.cost_model import COST_MODEL_VERSION
from repro.tuna.db import (
    Key,
    ScheduleDatabase,
    ScheduleRecord,
    query_index,
    record_beats,
)

SNAPSHOT_SCHEMA = "tuna-snapshot-v1"
POINTER_SCHEMA = "tuna-snapshot-pointer-v1"


class StaleSnapshotError(ValueError):
    """Snapshot was built under a different ``COST_MODEL_VERSION`` than
    this process runs: loading it would silently miss on every lookup."""


class StaleSnapshotWarning(UserWarning):
    """A stale snapshot was loaded anyway (``allow_stale=True``)."""


def _payload(records: Sequence[Dict]) -> str:
    # canonical serialization shared by save() and load(): json round-trips
    # floats via shortest-repr, so dump(load(dump(x))) == dump(x)
    return json.dumps(list(records), sort_keys=True, default=float)


def read_snapshot_header(path: Optional[str] = None, *,
                         data: Optional[str] = None,
                         prefix_chars: int = 8192) -> Dict:
    """Snapshot/pointer header without parsing the record array.

    Snapshots are written header-first (``records`` is the final key), so
    the identity fields — ``schema``, ``sha1``, ``cost_model_version``,
    ``count`` — live in the first few KB: slice the text before the
    ``"records"`` key and close the object. This is what makes snapshot
    revalidation cheap enough to run between serving waves (a full parse
    of a large snapshot is exactly the cost hot reload must avoid).
    Falls back to a full parse for pre-header-first files. Raises
    ``ValueError`` when the file is not a snapshot or pointer at all.
    """
    if data is None:
        with open(path, "r", encoding="utf-8") as f:
            data = f.read(prefix_chars + 1)
    head = data[:prefix_chars]
    cut = head.find('"records"')
    if cut != -1:
        frag = head[:cut].rstrip().rstrip(",") + "}"
        try:
            hdr = json.loads(frag)
        except ValueError:
            hdr = None
        if hdr is not None and "schema" in hdr and "sha1" in hdr:
            return hdr
    # fallback: pointer files (no records key), legacy sorted-key
    # snapshots, or headers larger than the probe window
    if path is not None and len(data) > prefix_chars:
        with open(path, "r", encoding="utf-8") as f:
            data = f.read()
    obj = json.loads(data)
    if not isinstance(obj, dict) or "schema" not in obj:
        raise ValueError("not a schedule snapshot or pointer")
    obj.pop("records", None)
    return obj


class ScheduleCache:
    """Immutable best-record index with O(1) lock-free lookups."""

    immutable = True  # write paths (tuner write-backs) check this flag

    def __init__(self, records: Sequence[ScheduleRecord],
                 source: str = "<memory>"):
        best: Dict[Key, ScheduleRecord] = {}
        for rec in records:
            cur = best.get(rec.key)
            if cur is None or record_beats(rec, cur):
                best[rec.key] = rec
        self._best = best
        self.source = source
        self.sha1: Optional[str] = None  # payload digest; set by save/load
        self.built_at: Optional[float] = None  # wall-clock build stamp; set
        #   by save/load (None for pre-stamp snapshots) — what the
        #   controller's snapshot_age_seconds gauge is computed from
        self.cost_model_version = COST_MODEL_VERSION
        self.stale = False  # True only for allow_stale version-mismatch loads
        self.hits = 0    # serving stats: plain ints, never locked (exact
        self.misses = 0  # under the GIL, approximate under free threading)

    # -- build / persist -------------------------------------------------

    @classmethod
    def from_db(cls, db: ScheduleDatabase) -> "ScheduleCache":
        return cls(db.records(), source=db.path or "<memory>")

    @classmethod
    def build(cls, db: Union[str, os.PathLike, ScheduleDatabase],
              out_path: str) -> "ScheduleCache":
        """Compile a store (path or instance) into a snapshot file."""
        if not isinstance(db, ScheduleDatabase):
            db = ScheduleDatabase(os.fspath(db))
        cache = cls.from_db(db)
        cache.save(out_path)
        return cache

    def payload_sha1(self) -> str:
        """Content digest over the canonical record payload — the snapshot
        identity used by manifests, versioned names, and hot-reload
        revalidation. Memoised (the record set is immutable)."""
        if self.sha1 is None:
            records = [dataclasses.asdict(r) for r in self.records()]
            self.sha1 = hashlib.sha1(_payload(records).encode()).hexdigest()
        return self.sha1

    def save(self, out_path: str) -> int:
        """Write the snapshot (atomic temp-file + replace), header fields
        before the record array so ``read_snapshot_header`` stays cheap;
        returns the record count."""
        records = [dataclasses.asdict(r) for r in self.records()]
        # built_at sits in the header (before "records", so the cheap
        # header probe sees it) but outside the sha1 payload: rebuilding
        # identical content at a later time keeps the same content address
        self.built_at = round(time.time(), 3)
        obj = {
            "schema": SNAPSHOT_SCHEMA,
            "cost_model_version": COST_MODEL_VERSION,
            "count": len(records),
            "sha1": self.payload_sha1(),
            "built_at": self.built_at,
            "source": self.source,
            "records": records,
        }
        d = os.path.dirname(out_path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".snapshot.tmp")
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(obj, f, default=float)
                f.write("\n")
            os.replace(tmp, out_path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return len(records)

    @classmethod
    def load(cls, path: str, allow_stale: bool = False) -> "ScheduleCache":
        """Load + verify a snapshot; follows a ``latest`` pointer file.

        Raises ``ValueError`` on schema mismatch or digest corruption and
        ``StaleSnapshotError`` when the snapshot was built under a
        different ``COST_MODEL_VERSION`` (every lookup would miss — the
        version is part of the key — so serving would silently pay full
        searches). ``allow_stale=True`` downgrades that to a
        ``StaleSnapshotWarning`` and marks the instance ``.stale``."""
        path = os.fspath(path)
        with open(path, "r", encoding="utf-8") as f:
            obj = json.load(f)
        if isinstance(obj, dict) and obj.get("schema") == POINTER_SCHEMA:
            target = os.path.join(os.path.dirname(os.path.abspath(path)),
                                  obj["snapshot"])
            return cls.load(target, allow_stale=allow_stale)
        if not isinstance(obj, dict) or obj.get("schema") != SNAPSHOT_SCHEMA:
            schema = obj.get("schema") if isinstance(obj, dict) else None
            raise ValueError(
                f"{path}: not a schedule snapshot "
                f"(schema={schema!r}, want {SNAPSHOT_SCHEMA!r})")
        digest = hashlib.sha1(_payload(obj["records"]).encode()).hexdigest()
        if digest != obj.get("sha1"):
            raise ValueError(
                f"{path}: snapshot digest mismatch (corrupt or torn copy); "
                f"rebuild with `python -m repro.tuna snapshot`")
        snap_version = obj.get("cost_model_version")
        stale = snap_version != COST_MODEL_VERSION
        if stale:
            msg = (
                f"{path}: snapshot was built for cost-model version "
                f"{snap_version!r} but this process runs "
                f"{COST_MODEL_VERSION!r}; the version is part of every "
                f"record key, so serving it would miss on every lookup. "
                f"Rebuild it: `python -m repro.tuna snapshot` (to inspect "
                f"it anyway: allow_stale=True, or `python -m repro.tuna "
                f"query --snapshot ... --allow-stale`)")
            if not allow_stale:
                raise StaleSnapshotError(msg)
            warnings.warn(msg, StaleSnapshotWarning, stacklevel=2)
        records = [ScheduleRecord.from_dict(r) for r in obj["records"]]
        cache = cls(records, source=obj.get("source", path))
        cache.sha1 = obj["sha1"]
        cache.built_at = obj.get("built_at")  # None: pre-stamp snapshot
        cache.cost_model_version = snap_version
        cache.stale = stale
        return cache

    # -- reads (the serving hot path) ------------------------------------

    def best(self, op: str, target: str,
             version: str = COST_MODEL_VERSION) -> Optional[ScheduleRecord]:
        rec = self._best.get((op, target, version))
        if rec is None:
            self.misses += 1
        else:
            self.hits += 1
        return rec

    def query(self, op: Optional[str] = None, target: Optional[str] = None,
              version: Optional[str] = None) -> List[ScheduleRecord]:
        """Same filter semantics as ``ScheduleDatabase.query`` (shared
        implementation, so the stores cannot diverge)."""
        return query_index(self._best, op=op, target=target, version=version)

    def records(self) -> List[ScheduleRecord]:
        return [self._best[k] for k in sorted(self._best)]

    def add(self, *args, **kwargs):
        raise TypeError(
            "ScheduleCache is an immutable snapshot; write to the "
            "ScheduleDatabase and rebuild (`python -m repro.tuna snapshot`)")

    def __len__(self) -> int:
        return len(self._best)

    def __contains__(self, key: Key) -> bool:
        return key in self._best


# -- snapshot lifecycle ----------------------------------------------------

@dataclasses.dataclass
class SnapshotInfo:
    """What ``SnapshotManager.ensure`` did: the versioned snapshot path,
    the ``latest`` pointer path, and whether anything changed."""

    name: str
    path: str
    latest: str
    sha1: str
    count: int
    rebuilt: bool     # a new versioned snapshot file was written
    repointed: bool   # the latest pointer moved
    built_at: Optional[float] = None  # wall-clock stamp of the snapshot
    #   file latest points at (survives no-op ensures: age keeps growing)


class SnapshotManager:
    """Keeps a directory of versioned snapshots consistent with a store.

    Snapshot identity is content-addressed: the versioned name embeds the
    builder's ``COST_MODEL_VERSION`` and the record-payload sha1, so a
    cost-model bump *or* any store change yields a new name — ``ensure``
    rebuilds exactly when identity changes and is a cheap no-op otherwise
    (re-publishing after every fleet sync is safe to cron). The ``latest``
    pointer (schema ``tuna-snapshot-pointer-v1``, atomic replace) is the
    stable path serving processes watch: ``ScheduleCache.load`` follows
    it, and ``core.tuner.refresh_default_cache`` revalidates through its
    sha1 field without touching the record payload.
    """

    def __init__(self, db_path: str, out_dir: str,
                 prefix: str = "schedule_cache"):
        self.db_path = os.fspath(db_path)
        self.out_dir = os.fspath(out_dir)
        self.prefix = prefix

    @property
    def latest_path(self) -> str:
        return os.path.join(self.out_dir, f"{self.prefix}.latest.json")

    def snapshot_name(self, sha1: str) -> str:
        return f"{self.prefix}.{COST_MODEL_VERSION}-{sha1[:12]}.json"

    def current(self) -> Optional[Dict]:
        """The latest pointer's header, or None when never published."""
        try:
            return read_snapshot_header(self.latest_path)
        except (FileNotFoundError, ValueError):
            return None

    def ensure(self, force: bool = False) -> SnapshotInfo:
        """Bring the snapshot directory up to date with the store: write
        the versioned snapshot if its content-addressed name is missing
        (or ``force``), and repoint ``latest`` at it. Old versioned
        snapshots are left in place — in-flight pulls and still-running
        serve processes keep a consistent artifact until they refresh."""
        cache = ScheduleCache.from_db(ScheduleDatabase(self.db_path))
        digest = cache.payload_sha1()
        name = self.snapshot_name(digest)
        path = os.path.join(self.out_dir, name)
        rebuilt = force or not os.path.exists(path)
        if rebuilt:
            cache.save(path)
            built_at = cache.built_at
        else:  # no-op ensure: the artifact keeps its original build stamp
            try:
                built_at = read_snapshot_header(path).get("built_at")
            except (OSError, ValueError):
                built_at = None
        cur = self.current()
        repointed = cur is None or cur.get("snapshot") != name
        if repointed:
            self._write_pointer(name, digest, len(cache), built_at)
        return SnapshotInfo(name=name, path=path, latest=self.latest_path,
                            sha1=digest, count=len(cache),
                            rebuilt=rebuilt, repointed=repointed,
                            built_at=built_at)

    def _write_pointer(self, name: str, sha1: str, count: int,
                       built_at: Optional[float] = None) -> None:
        obj = {
            "schema": POINTER_SCHEMA,
            "snapshot": name,
            "sha1": sha1,
            "count": count,
            "built_at": built_at,
            "cost_model_version": COST_MODEL_VERSION,
        }
        os.makedirs(self.out_dir, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.out_dir, suffix=".pointer.tmp")
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(obj, f, sort_keys=True)
                f.write("\n")
            os.replace(tmp, self.latest_path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def publish(self, transport,
                info: Optional[SnapshotInfo] = None) -> List:
        """``ensure`` + push the versioned snapshot and the ``latest``
        pointer over a transport (spec or instance). Pass the
        ``SnapshotInfo`` from an ``ensure()`` you already ran to skip a
        second store load + digest pass. Pushing the payload before the
        pointer means a puller that sees the new pointer can always pull
        the snapshot it names. Returns the manifests."""
        from repro.tuna.transport import resolve_transport

        t = resolve_transport(transport)
        if info is None:
            info = self.ensure()
        manifests = [t.push(info.path, info.name)]
        manifests.append(t.push(self.latest_path,
                                os.path.basename(self.latest_path)))
        return manifests
