"""``python -m repro.tuna`` — operate the persistent schedule database.

Subcommands:
  tune     fan (ops × targets) jobs across a worker pool into the DB;
           --num-shards/--shard-id take one deterministic slice of the
           matrix into a per-shard store (the fleet write path);
           --transport pushes the finished store into a channel
  sync     merge per-shard stores back into the base store (+ provenance);
           --transport pulls shard stores from a channel (verified) first;
           --verify fails on any divergence from a reference store and on
           any corrupt/torn source line dropped during the merge
  snapshot compile the store into an immutable serving cache (JSON + sha1);
           --dir keeps a versioned snapshot + `latest` pointer lifecycle;
           --publish pushes the artifact over a transport
  query    print best records (filter by --op prefix / --target /
           --version; --snapshot reads a compiled cache instead of the DB —
           a stale-version snapshot is an error unless --allow-stale;
           --json emits one array with the same serialization the
           controller's /schedule endpoint uses)
  controller
           run the fleet as a daemon: dispatch shard workers under leases,
           heal crashes/expiries, sync + verify, republish snapshots, and
           serve GET /schedule /healthz /metrics (Prometheus text) —
           see repro.tuna.controller
  golden   freeze the store into a blessed, content-addressed golden
           release per (target, cost-model version), regression-gated
           against the previous golden (--waive records explicit
           exceptions in the manifest); --bundle AOT-compiles every
           scheduled Pallas kernel into a serialized-executable bundle
           (serve cold-start skips compilation); --publish ships both
           over a transport — see repro.tuna.golden
  train    fit the learned ranker (repro.core.learned) offline from the
           store's full log — datasheet cm1, calibrated, and measured
           (cm1-meas) lineages standardised separately; keeps versioned,
           content-addressed artifacts (learned.<version>-<digest>.json)
           plus a `latest` pointer, retrained only when the store's
           training content or cost-model version changed; --transport
           pulls the fleet's shard stores first, --publish ships the
           artifact over a transport
  eval     judge a trained artifact against the store: per-lineage rank
           correlation (Spearman) between learned predictions and stored
           scores; --check gates the mean
  compact  rewrite the log keeping only the best record per key;
           --transport pulls the fleet's shard stores first (then pushes
           the compacted store back); bare per-shard siblings on disk are
           a fail-fast error unless --ignore-shards
  export   dump best records as a JSON array (same --transport/shard
           discipline as compact)

Transports (see repro.tuna.transport): dir:///path (or a bare path) is a
directory bucket; mem://name is the in-process test channel.

Fleet workflow with no shared filesystem (each host owns a shard id):
  python -m repro.tuna tune --db db.jsonl --num-shards 4 --shard-id 2 \
      --transport dir:///var/tuna/bucket
  python -m repro.tuna sync --db db.jsonl --num-shards 4 \
      --transport dir:///var/tuna/bucket
  python -m repro.tuna snapshot --db db.jsonl --dir snapshots/ \
      --publish dir:///var/tuna/bucket
  python -m repro.tuna query --snapshot snapshots/schedule_cache.latest.json

Examples:
  python -m repro.tuna tune --ops dense_256,conv2d --targets tpu_v5e,cpu_avx2
  python -m repro.tuna tune --smoke          # CI cold-start check
  python -m repro.tuna query --op matmul --target tpu_v5e
  python -m repro.tuna compact
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.configs.tuna_ops import OPERATORS, SMOKE_OPERATORS
from repro.hw import TARGETS
from repro.tuna import orchestrator
from repro.tuna.db import ScheduleDatabase

DEFAULT_DB = "experiments/schedule_db.jsonl"


def _csv(s: str) -> List[str]:
    return [x for x in (p.strip() for p in s.split(",")) if x]


def cmd_tune(args: argparse.Namespace) -> int:
    if args.smoke:
        ops = list(SMOKE_OPERATORS)
        targets = ["tpu_v5e"]
        workers = min(args.workers, 2)
        limit = min(args.limit, 256)
    else:
        ops = _csv(args.ops) if args.ops != "all" else list(OPERATORS)
        targets = _csv(args.targets)
        workers, limit = args.workers, args.limit
    for op in ops:
        if op not in OPERATORS:
            print(f"error: unknown operator {op!r}; have {sorted(OPERATORS)}",
                  file=sys.stderr)
            return 2
    for t in targets:
        if t not in TARGETS:
            print(f"error: unknown target {t!r}; have {sorted(TARGETS)}",
                  file=sys.stderr)
            return 2
    jobs = orchestrator.jobs_for(ops, targets, strategy=args.strategy,
                                 limit=limit, seed=args.seed)
    db_path = args.db
    if args.num_shards < 1:
        print("error: --num-shards must be >= 1", file=sys.stderr)
        return 2
    if not 0 <= args.shard_id < args.num_shards:
        print(f"error: --shard-id must be in [0, {args.num_shards})",
              file=sys.stderr)
        return 2
    if args.num_shards > 1 or args.as_shard:
        from repro.tuna import fleet

        jobs = fleet.shard_jobs(jobs, args.num_shards, args.shard_id)
        # even an empty shard leaves a store file so sync can tell
        # "finished with no jobs" apart from "crashed"
        db_path = fleet.touch_store(
            fleet.shard_store_path(args.db, args.shard_id))
        print(f"[tuna] shard {args.shard_id}/{args.num_shards}: "
              f"{len(jobs)} jobs -> {db_path}")
    db = ScheduleDatabase(db_path)
    report = orchestrator.run(jobs, db=db, workers=workers,
                              retries=args.retries, verbose=True)
    print(f"[tuna] {len(report.records)}/{len(jobs)} jobs done in "
          f"{report.wall_seconds:.1f}s -> {db_path} ({len(db)} keys)")
    for fail in report.failures:
        print(f"[tuna] FAILED {fail.job.op} @ {fail.job.target} after "
              f"{fail.attempts} attempts:\n{fail.error}", file=sys.stderr)
    if args.transport:
        from repro.tuna import fleet
        from repro.tuna.transport import resolve_transport

        t = resolve_transport(args.transport)
        # always push under the shard object name (shard 0 for an
        # unsharded run): `sync --transport` only ever pulls shard names,
        # so a base-named push would be unreachable
        man = t.push(db_path, fleet.shard_object_name(args.db, args.shard_id))
        print(f"[tuna] pushed {man.name} ({man.records} records, "
              f"sha1 {man.sha1[:12]}) -> {t.describe()}")
    return 0 if report.ok else 1


def cmd_sync(args: argparse.Namespace) -> int:
    from repro.tuna import fleet

    rep = fleet.sync(args.db, args.num_shards,
                     provenance=not args.no_provenance,
                     compact=not args.no_compact,
                     transport=args.transport or None,
                     staging_dir=args.staging_dir)
    for name in rep.pulled:
        print(f"[tuna] pulled {name} (verified)")
    for path, n in rep.absorbed.items():
        print(f"[tuna] {path}: absorbed {n} records")
    for path in rep.skipped:
        print(f"[tuna] missing shard store {path} (skipped; re-run sync "
              f"after the shard finishes)", file=sys.stderr)
    if rep.corrupt_lines:
        print(f"[tuna] WARNING: dropped {rep.corrupt_lines} corrupt/torn "
              f"source line(s) during merge "
              f"({ {p: n for p, n in rep.corrupt.items() if n} }); "
              f"re-run sync once the shard writers finish", file=sys.stderr)
    print(f"[tuna] synced {args.db}: {rep.keys} keys from "
          f"{args.num_shards - len(rep.skipped)}/{args.num_shards} shards")
    if args.verify:
        ref = ScheduleDatabase(args.verify)
        div = fleet.divergence(rep.db, ref, label_a=args.db,
                               label_b=args.verify)
        if div:
            print("[tuna] MERGE DIVERGENCE:", file=sys.stderr)
            for msg in div:
                print(f"  {msg}", file=sys.stderr)
            return 1
        if rep.corrupt_lines:
            print("[tuna] --verify: corrupt source lines were dropped — "
                  "the merge is not lossless, failing", file=sys.stderr)
            return 1
        print(f"[tuna] verified against {args.verify}: no divergence")
    return 0


def cmd_snapshot(args: argparse.Namespace) -> int:
    from repro.tuna.cache import ScheduleCache, SnapshotManager

    if args.dir:
        mgr = SnapshotManager(args.db, args.dir)
        info = mgr.ensure(force=args.force)
        state = "rebuilt" if info.rebuilt else "up to date"
        print(f"[tuna] snapshot {info.path}: {info.count} records ({state}; "
              f"latest -> {info.name})")
        if args.publish:
            from repro.tuna.transport import resolve_transport

            t = resolve_transport(args.publish)
            for man in mgr.publish(t, info=info):
                print(f"[tuna] published {man.name} ({man.size}B, "
                      f"sha1 {man.sha1[:12]}) -> {t.describe()}")
        return 0
    cache = ScheduleCache.build(args.db, args.out)
    print(f"[tuna] snapshot {args.out}: {len(cache)} records from {args.db}")
    if args.publish:
        from repro.tuna.transport import resolve_transport

        t = resolve_transport(args.publish)
        man = t.push(args.out)
        print(f"[tuna] published {man.name} ({man.records} records, "
              f"sha1 {man.sha1[:12]}) -> {t.describe()}")
    return 0


def cmd_query(args: argparse.Namespace) -> int:
    if args.snapshot:
        from repro.tuna.cache import ScheduleCache, StaleSnapshotError

        try:
            store = ScheduleCache.load(args.snapshot,
                                       allow_stale=args.allow_stale)
        except StaleSnapshotError as e:
            print(f"error: {e}", file=sys.stderr)
            return 1
        if store.stale:
            print(f"[tuna] WARNING: serving a stale snapshot (built for "
                  f"cost-model version {store.cost_model_version!r})",
                  file=sys.stderr)
    else:
        store = ScheduleDatabase(args.db)
    from repro.tuna.db import record_to_dict

    recs = store.query(op=args.op, target=args.target, version=args.version)
    if args.json:
        # one serializer shared with the controller's /schedule endpoint
        # (db.record_to_dict): scripts can diff the two without caring
        # which side of the service they asked
        print(json.dumps([record_to_dict(r) for r in recs], indent=2,
                         sort_keys=True, default=float))
        return 0 if recs else 1
    if not recs:
        print("no matching records", file=sys.stderr)
        return 1
    for rec in recs:
        print(json.dumps(record_to_dict(rec), sort_keys=True, default=float))
    return 0


def cmd_controller(args: argparse.Namespace) -> int:
    from repro.tuna.controller import (ControllerConfig, FleetController,
                                       start_http)

    if args.smoke:
        ops = list(SMOKE_OPERATORS)
        targets = ["tpu_v5e"]
        limit = min(args.limit, 256)
    else:
        ops = _csv(args.ops) if args.ops != "all" else list(OPERATORS)
        targets = _csv(args.targets)
        limit = args.limit
    for op in ops:
        if op not in OPERATORS:
            print(f"error: unknown operator {op!r}; have {sorted(OPERATORS)}",
                  file=sys.stderr)
            return 2
    for t in targets:
        if t not in TARGETS:
            print(f"error: unknown target {t!r}; have {sorted(TARGETS)}",
                  file=sys.stderr)
            return 2
    cfg = ControllerConfig(
        db=args.db, ops=ops, targets=targets, num_shards=args.num_shards,
        strategy=args.strategy, limit=limit, seed=args.seed,
        transport=args.transport or None,
        snapshot_dir=args.snapshot_dir, publish=args.publish or None,
        learned_dir=args.learned_dir,
        lease_s=args.lease_s, poll_s=args.poll_s,
        max_attempts=args.max_attempts, max_workers=args.max_workers,
        worker_procs=args.workers, worker_retries=args.retries,
        worker_mode=args.worker_mode,
        inject_crash_shard=args.inject_crash_shard,
    )
    ctl = FleetController(cfg)
    server = None
    if args.port is not None:
        server = start_http(ctl, host=args.host, port=args.port)
        host, port = server.server_address[:2]
        print(f"[controller] serving http://{host}:{port} "
              f"(/schedule /healthz /metrics)", flush=True)

    import signal

    def _stop(signum, frame):
        print(f"[controller] signal {signum}: shutting down", flush=True)
        ctl.stop()

    for sig in (signal.SIGINT, signal.SIGTERM):
        signal.signal(sig, _stop)
    try:
        rc = ctl.run(max_rounds=args.max_rounds or None,
                     exit_when_converged=args.exit_when_converged)
    finally:
        if server is not None:
            server.shutdown()
            server.server_close()
    state = "converged" if ctl.converged else \
        ("wedged" if ctl.wedged else "stopped")
    print(f"[controller] exit: {state}, "
          f"{int(ctl.metrics.get('jobs_done_total'))} jobs done, "
          f"{int(ctl.metrics.get('shards_healed_total'))} shards healed, "
          f"{ctl._store_records} store records", flush=True)
    return rc


def cmd_golden(args: argparse.Namespace) -> int:
    from repro.core.cost_model import COST_MODEL_VERSION
    from repro.tuna.golden import (
        GoldenError,
        GoldenManager,
        GoldenRegressionError,
        build_kernel_bundle,
    )

    if args.snapshot:
        from repro.tuna.cache import ScheduleCache, StaleSnapshotError

        try:
            store = ScheduleCache.load(args.snapshot)
        except StaleSnapshotError as e:
            print(f"error: {e}", file=sys.stderr)
            return 1
        source = args.snapshot
    else:
        store = ScheduleDatabase(args.db)
        source = args.db
    records = store.records()
    if args.targets == "all":
        targets = sorted({r.target for r in records
                          if r.version == COST_MODEL_VERSION})
    else:
        targets = _csv(args.targets)
    if not targets:
        print(f"error: {source}: no records under cost-model version "
              f"{COST_MODEL_VERSION!r} — tune first", file=sys.stderr)
        return 2
    mgr = GoldenManager(args.dir)
    rc = 0
    for target in targets:
        try:
            info = mgr.promote(records, target, waive=args.waive or (),
                               force=args.force, source=source)
        except GoldenRegressionError as e:
            print(f"[tuna] REFUSED golden promotion for {target}: {e}",
                  file=sys.stderr)
            rc = 1
            continue
        except GoldenError as e:
            print(f"error: {e}", file=sys.stderr)
            rc = rc or 2
            continue
        state = "promoted" if info.rebuilt else "up to date"
        gate = (f"gated against {info.predecessor}, "
                f"{info.gated_against} schedules checked"
                if info.predecessor else "first release in this lineage")
        print(f"[tuna] golden {info.name}: {info.count} schedules "
              f"({state}; {gate}; latest -> {info.name})")
        for w in info.waived:
            print(f"[tuna]   WAIVED (--waive {w.waived_by!r}): "
                  f"{w.describe()}", file=sys.stderr)
        bundle = None
        if args.bundle:
            _, release = mgr.load_release(info.path)
            bundle = build_kernel_bundle(release, args.dir, target,
                                         golden_name=info.name)
            print(f"[tuna] bundle {bundle.name}: {bundle.entries} AOT "
                  f"kernel(s) over {bundle.schedules} schedules")
            for op, why in bundle.skipped:
                print(f"[tuna]   no AOT kernel for {op}: {why}")
        if args.publish:
            from repro.tuna.transport import resolve_transport

            t = resolve_transport(args.publish)
            for man in mgr.publish(t, info, bundle=bundle):
                print(f"[tuna] published {man.name} ({man.size}B, "
                      f"sha1 {man.sha1[:12]}) -> {t.describe()}")
    return rc


def cmd_train(args: argparse.Namespace) -> int:
    rc = _pull_fleet_or_fail(args, "train")
    if rc:
        return rc
    from repro.tuna.learned import LearnedManager

    mgr = LearnedManager(args.db, args.dir, augment=args.augment,
                         seed=args.seed, l2=args.l2)
    try:
        info = mgr.ensure(force=args.force)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    state = "retrained" if info.retrained else "up to date"
    print(f"[tuna] learned {info.path}: version {info.version}, "
          f"{info.samples} samples ({info.skipped} rows skipped; {state}; "
          f"latest -> {info.name})")
    if args.publish:
        from repro.tuna.transport import resolve_transport

        t = resolve_transport(args.publish)
        for man in mgr.publish(t, info=info):
            print(f"[tuna] published {man.name} ({man.size}B, "
                  f"sha1 {man.sha1[:12]}) -> {t.describe()}")
    return 0


def cmd_eval(args: argparse.Namespace) -> int:
    from repro.core.learned import load_ranker, spearman
    from repro.tuna.cache import StaleSnapshotError
    from repro.tuna.learned import (build_dataset, iter_log_records,
                                    training_rows)

    try:
        model = load_ranker(args.model)
    except (StaleSnapshotError, ValueError, FileNotFoundError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    rows = training_rows(iter_log_records(args.db))
    X, y, groups, skipped = build_dataset(rows)
    if len(y) < 3:
        print(f"error: {args.db}: only {len(y)} usable eval sample(s) "
              f"({skipped} skipped)", file=sys.stderr)
        return 1
    import math

    import numpy as np

    preds = model.predict(X)
    logy = np.log(np.maximum(y, 1e-30))
    per_group = {}
    for g in sorted(set(groups)):
        m = np.asarray([gi == g for gi in groups])
        if m.sum() >= 3:
            per_group[g] = spearman(preds[m], logy[m])
    print(f"[tuna] eval {args.model}: version {model.version}, "
          f"{len(y)} samples, {len(per_group)} group(s)")
    for g, rho in sorted(per_group.items()):
        print(f"  spearman={rho:+.3f}  {g}")
    if not per_group:
        print("error: no group has >= 3 samples to rank", file=sys.stderr)
        return 1
    mean_rho = sum(per_group.values()) / len(per_group)
    print(f"[tuna] mean spearman {mean_rho:+.3f} "
          f"(rank correlation, 1.0 = perfect ordering)")
    if args.check and (math.isnan(mean_rho)
                       or mean_rho < args.min_spearman):
        print(f"CHECK FAILED: mean spearman {mean_rho:.3f} < "
              f"{args.min_spearman}", file=sys.stderr)
        return 1
    if args.check:
        print(f"CHECK OK: mean spearman {mean_rho:.3f} >= "
              f"{args.min_spearman}")
    return 0


def _shard_siblings(db_path: str) -> List[str]:
    """Per-shard stores sitting next to a base store on disk
    (``db.jsonl`` -> ``db.shardNN.jsonl``), the layout ``tune
    --num-shards`` writes."""
    import glob

    root, ext = os.path.splitext(os.fspath(db_path))
    return sorted(glob.glob(f"{root}.shard[0-9][0-9]{ext or '.jsonl'}"))


def _pull_fleet_or_fail(args: argparse.Namespace, cmd: str) -> int:
    """Whole-store guard shared by compact/export: both commands claim to
    operate on *the* store, so running them against the base file while a
    fleet publishes per-shard stores silently works on a stale partial
    copy. With --transport, pull + merge every published shard first
    (sync's verified path); otherwise refuse when shard siblings exist on
    disk, unless the operator says --ignore-shards."""
    if args.transport:
        if not args.num_shards:
            print(f"error: {cmd} --transport needs --num-shards to know "
                  f"which shard stores to pull", file=sys.stderr)
            return 2
        from repro.tuna import fleet

        rep = fleet.sync(args.db, args.num_shards, compact=False,
                         transport=args.transport,
                         staging_dir=args.staging_dir)
        for name in rep.pulled:
            print(f"[tuna] pulled {name} (verified)")
        for path in rep.skipped:
            print(f"[tuna] WARNING: shard store {path} not published yet "
                  f"(skipped) — the {cmd} covers a partial fleet",
                  file=sys.stderr)
        return 0
    shards = _shard_siblings(args.db)
    if shards and not args.ignore_shards:
        print(f"error: {args.db} has {len(shards)} per-shard store(s) "
              f"next to it ({', '.join(os.path.basename(s) for s in shards)}) "
              f"— {cmd}ing only the base store would operate on a stale "
              f"partial copy. Run `python -m repro.tuna sync --db {args.db} "
              f"--num-shards N` first, pass --transport to pull the fleet's "
              f"shards here, or pass --ignore-shards to {cmd} just the "
              f"base store anyway", file=sys.stderr)
        return 2
    return 0


def cmd_compact(args: argparse.Namespace) -> int:
    rc = _pull_fleet_or_fail(args, "compact")
    if rc:
        return rc
    db = ScheduleDatabase(args.db)
    dropped = db.compact()
    print(f"[tuna] compacted {args.db}: {len(db)} keys kept, "
          f"{dropped} superseded lines dropped")
    if args.transport:
        from repro.tuna.transport import resolve_transport

        # push the compacted store back under its base name: the channel's
        # authoritative merged object for downstream pulls (sync only ever
        # pulls shard-named objects, so this can't shadow a shard store)
        t = resolve_transport(args.transport)
        man = t.push(args.db, os.path.basename(args.db))
        print(f"[tuna] pushed {man.name} ({man.records} records, "
              f"sha1 {man.sha1[:12]}) -> {t.describe()}")
    return 0


def cmd_export(args: argparse.Namespace) -> int:
    rc = _pull_fleet_or_fail(args, "export")
    if rc:
        return rc
    db = ScheduleDatabase(args.db)
    n = db.export(args.out)
    print(f"[tuna] exported {n} records -> {args.out}")
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="repro.tuna", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("tune", help="run tuning jobs into the DB")
    p.add_argument("--db", default=DEFAULT_DB)
    p.add_argument("--ops", default="all",
                   help="comma-separated configs.tuna_ops names, or 'all'")
    p.add_argument("--targets", default="tpu_v5e,cpu_avx2,gpu_a100")
    p.add_argument("--strategy", choices=["exhaustive", "es"],
                   default="exhaustive")
    p.add_argument("--workers", type=int, default=4)
    p.add_argument("--retries", type=int, default=2)
    p.add_argument("--limit", type=int, default=1024)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--smoke", action="store_true",
                   help="tiny fixed job set (CI cold-start check)")
    p.add_argument("--num-shards", type=int, default=1,
                   help="fleet size: stable-hash the job matrix into this "
                        "many disjoint shards")
    p.add_argument("--shard-id", type=int, default=0,
                   help="which shard this host owns (writes to "
                        "<db>.shardNN.jsonl)")
    p.add_argument("--as-shard", action="store_true",
                   help="use the per-shard store layout even with "
                        "--num-shards 1 (what controller workers pass, so "
                        "sync/heal semantics hold for one-shard fleets)")
    p.add_argument("--transport", default=None, metavar="SPEC",
                   help="push the finished store into this channel "
                        "(dir:///path, mem://bucket, or a bare directory) "
                        "so the sync host needs no shared filesystem")
    p.set_defaults(fn=cmd_tune)

    p = sub.add_parser("sync", help="merge per-shard stores into the base DB")
    p.add_argument("--db", default=DEFAULT_DB, help="base store path")
    p.add_argument("--num-shards", type=int, required=True)
    p.add_argument("--no-provenance", action="store_true",
                   help="do not stamp meta.provenance on absorbed records")
    p.add_argument("--no-compact", action="store_true",
                   help="keep the merged log uncompacted")
    p.add_argument("--transport", default=None, metavar="SPEC",
                   help="pull shard stores from this channel (integrity-"
                        "verified) instead of the shared filesystem")
    p.add_argument("--staging-dir", default=None,
                   help="where transport pulls land (default "
                        "<db>.staging/)")
    p.add_argument("--verify", default=None, metavar="REF_DB",
                   help="fail (exit 1) if the merged store diverges from "
                        "this reference store, or if any corrupt source "
                        "line was dropped")
    p.set_defaults(fn=cmd_sync)

    p = sub.add_parser("snapshot",
                       help="compile the store into a serving cache")
    p.add_argument("--db", default=DEFAULT_DB)
    p.add_argument("--out", default="experiments/schedule_cache.json")
    p.add_argument("--dir", default=None, metavar="OUT_DIR",
                   help="snapshot lifecycle mode: keep versioned snapshots "
                        "(<prefix>.<cm-version>-<digest>.json) plus a "
                        "`latest` pointer in this directory; rebuilds only "
                        "when the store or cost-model version changed")
    p.add_argument("--force", action="store_true",
                   help="with --dir: rewrite the snapshot even if current")
    p.add_argument("--publish", default=None, metavar="SPEC",
                   help="push the snapshot (and, with --dir, the latest "
                        "pointer) over this transport")
    p.set_defaults(fn=cmd_snapshot)

    p = sub.add_parser("query", help="print best records")
    p.add_argument("--db", default=DEFAULT_DB)
    p.add_argument("--snapshot", default=None,
                   help="query a compiled snapshot (or a `latest` pointer) "
                        "instead of the JSONL DB")
    p.add_argument("--allow-stale", action="store_true",
                   help="load a snapshot built under a different cost-model "
                        "version anyway (flagged on stderr) instead of "
                        "failing")
    p.add_argument("--op", default=None, help="exact op signature or prefix")
    p.add_argument("--target", default=None)
    p.add_argument("--version", default=None)
    p.add_argument("--json", action="store_true",
                   help="emit one JSON array (same serialization as the "
                        "controller's /schedule endpoint) instead of "
                        "JSONL lines")
    p.set_defaults(fn=cmd_query)

    p = sub.add_parser(
        "controller",
        help="run the fleet controller daemon (dispatch + heal + sync + "
             "snapshot + HTTP schedule/metrics API)")
    p.add_argument("--db", default=DEFAULT_DB, help="base store path")
    p.add_argument("--ops", default="all",
                   help="comma-separated configs.tuna_ops names, or 'all'")
    p.add_argument("--targets", default="tpu_v5e,cpu_avx2,gpu_a100")
    p.add_argument("--strategy", choices=["exhaustive", "es"],
                   default="exhaustive")
    p.add_argument("--limit", type=int, default=1024)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--smoke", action="store_true",
                   help="tiny fixed job matrix (CI controller-smoke)")
    p.add_argument("--num-shards", type=int, default=2)
    p.add_argument("--transport", default=None, metavar="SPEC",
                   help="fleet channel the workers push shard stores into "
                        "and sync pulls from (dir:///path, mem://bucket)")
    p.add_argument("--snapshot-dir", default=None,
                   help="versioned snapshot + `latest` pointer directory "
                        "(default <db>.snapshots/)")
    p.add_argument("--publish", default=None, metavar="SPEC",
                   help="transport to publish snapshots over (what serving "
                        "hosts' refresh_default_cache watches)")
    p.add_argument("--learned-dir", default=None, metavar="OUT_DIR",
                   help="retrain + republish the learned ranker "
                        "(repro.tuna.learned.LearnedManager) into this "
                        "directory whenever the store's training content "
                        "changes — same ensure-on-change contract as "
                        "snapshots")
    p.add_argument("--port", type=int, default=None,
                   help="serve GET /schedule /healthz /metrics on this "
                        "port (0 = ephemeral, printed at boot; omit to "
                        "run headless)")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--lease-s", type=float, default=300.0,
                   help="shard lease: a worker silent past this is killed "
                        "and its shard re-dispatched")
    p.add_argument("--poll-s", type=float, default=0.5,
                   help="control-loop heartbeat interval")
    p.add_argument("--max-attempts", type=int, default=3,
                   help="dispatches per shard before giving up on it")
    p.add_argument("--max-workers", type=int, default=2,
                   help="concurrent shard workers")
    p.add_argument("--workers", type=int, default=2,
                   help="orchestrator process pool inside each worker")
    p.add_argument("--retries", type=int, default=2,
                   help="per-job retries inside each worker")
    p.add_argument("--worker-mode", choices=["auto", "process", "thread"],
                   default="auto",
                   help="auto = subprocess workers, in-process threads "
                        "for mem:// channels")
    p.add_argument("--max-rounds", type=int, default=0,
                   help="stop after this many control rounds (0 = run "
                        "until signalled)")
    p.add_argument("--exit-when-converged", "--once", action="store_true",
                   dest="exit_when_converged",
                   help="exit as soon as the fleet converges (or wedges) "
                        "instead of keeping watch")
    p.add_argument("--inject-crash-shard", type=int, default=None,
                   metavar="SHARD",
                   help="fault injection: this shard's first dispatch "
                        "dies before publishing (CI heal check)")
    p.set_defaults(fn=cmd_controller)

    p = sub.add_parser(
        "golden",
        help="freeze the store into a regression-gated golden release "
             "(+ optional AOT kernel bundle)")
    p.add_argument("--db", default=DEFAULT_DB)
    p.add_argument("--snapshot", default=None,
                   help="promote from a compiled snapshot (or `latest` "
                        "pointer) instead of the JSONL DB")
    p.add_argument("--dir", default="experiments/golden", metavar="OUT_DIR",
                   help="golden release directory: versioned releases "
                        "(golden.<target>.<cm-version>-<digest>.json) plus "
                        "a per-target `latest` pointer")
    p.add_argument("--targets", default="all",
                   help="comma-separated targets to promote, or 'all' "
                        "(every target present in the store for the "
                        "current cost-model version)")
    p.add_argument("--waive", action="append", default=None,
                   metavar="OP[@TARGET]",
                   help="accept a specific regression vs the previous "
                        "golden; repeatable, recorded in the release "
                        "manifest")
    p.add_argument("--force", action="store_true",
                   help="rewrite the release file even if its "
                        "content-addressed name already exists")
    p.add_argument("--bundle", action="store_true",
                   help="AOT-compile every scheduled Pallas kernel in the "
                        "release into a serialized-executable bundle "
                        "(bundle.<target>.<cm-version>-<digest>.json) — "
                        "what `launch/serve.py --kernel-bundle` loads")
    p.add_argument("--publish", default=None, metavar="SPEC",
                   help="push the release (+ bundle) and their `latest` "
                        "pointers over this transport")
    p.set_defaults(fn=cmd_golden)

    p = sub.add_parser(
        "train",
        help="fit the learned ranker offline from the store's log")
    p.add_argument("--db", default=DEFAULT_DB)
    p.add_argument("--dir", default="experiments/learned", metavar="OUT_DIR",
                   help="artifact directory: versioned models "
                        "(learned.<version>-<digest>.json) plus a `latest` "
                        "pointer; retrains only when the store's training "
                        "content or cost-model version changed")
    p.add_argument("--augment", type=int, default=0, metavar="N",
                   help="add up to N statically-scored configs per stored "
                        "(op, target) — free cm1-lineage samples for "
                        "spaces with few stored records")
    p.add_argument("--l2", type=float, default=1e-2,
                   help="ridge regularisation strength")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--force", action="store_true",
                   help="retrain even if the pointed artifact is current")
    p.add_argument("--transport", default=None, metavar="SPEC",
                   help="pull the fleet's published shard stores (needs "
                        "--num-shards) and merge them before training")
    p.add_argument("--num-shards", type=int, default=0,
                   help="fleet size for --transport pulls")
    p.add_argument("--staging-dir", default=None,
                   help="where transport pulls land (default <db>.staging/)")
    p.add_argument("--ignore-shards", action="store_true",
                   help="train on just the base store even when per-shard "
                        "stores sit next to it (default: fail fast)")
    p.add_argument("--publish", default=None, metavar="SPEC",
                   help="push the artifact and its `latest` pointer over "
                        "this transport")
    p.set_defaults(fn=cmd_train)

    p = sub.add_parser(
        "eval",
        help="rank-correlate a trained artifact against the store")
    p.add_argument("--db", default=DEFAULT_DB)
    p.add_argument("--model", default="experiments/learned/learned.latest.json",
                   help="artifact or `latest` pointer to evaluate")
    p.add_argument("--check", action="store_true",
                   help="exit 1 unless mean per-group spearman >= "
                        "--min-spearman")
    p.add_argument("--min-spearman", type=float, default=0.5,
                   help="gate for --check (1.0 = perfect ordering, 0 = "
                        "random)")
    p.set_defaults(fn=cmd_eval)

    p = sub.add_parser("compact", help="drop superseded log lines")
    p.add_argument("--db", default=DEFAULT_DB)
    p.add_argument("--transport", default=None, metavar="SPEC",
                   help="pull the fleet's published shard stores (needs "
                        "--num-shards) and merge them before compacting, "
                        "then push the compacted store back under its "
                        "base name")
    p.add_argument("--num-shards", type=int, default=0,
                   help="fleet size for --transport pulls")
    p.add_argument("--staging-dir", default=None,
                   help="where transport pulls land (default <db>.staging/)")
    p.add_argument("--ignore-shards", action="store_true",
                   help="compact just the base store even when per-shard "
                        "stores sit next to it (default: fail fast — the "
                        "base alone is a stale partial copy)")
    p.set_defaults(fn=cmd_compact)

    p = sub.add_parser("export", help="dump best records as JSON")
    p.add_argument("--db", default=DEFAULT_DB)
    p.add_argument("--out", default="experiments/schedule_db.json")
    p.add_argument("--transport", default=None, metavar="SPEC",
                   help="pull the fleet's published shard stores (needs "
                        "--num-shards) and merge them before exporting")
    p.add_argument("--num-shards", type=int, default=0,
                   help="fleet size for --transport pulls")
    p.add_argument("--staging-dir", default=None,
                   help="where transport pulls land (default <db>.staging/)")
    p.add_argument("--ignore-shards", action="store_true",
                   help="export just the base store even when per-shard "
                        "stores sit next to it (default: fail fast)")
    p.set_defaults(fn=cmd_export)
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except BrokenPipeError:
        # downstream head/pager closed the pipe: the unix-normal exit.
        # Re-point stdout at devnull so interpreter shutdown doesn't print
        # a spurious "Exception ignored" on the final flush.
        import os

        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        return 0


if __name__ == "__main__":
    raise SystemExit(main())
