"""``python -m repro.tuna`` — operate the persistent schedule database.

Subcommands:
  tune     fan (ops × targets) jobs across a worker pool into the DB
  query    print best records (filter by --op prefix / --target / --version)
  compact  rewrite the log keeping only the best record per key
  export   dump best records as a JSON array

Examples:
  python -m repro.tuna tune --ops dense_256,conv2d --targets tpu_v5e,cpu_avx2
  python -m repro.tuna tune --smoke          # CI cold-start check
  python -m repro.tuna query --op matmul --target tpu_v5e
  python -m repro.tuna compact
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import List, Optional

from repro.configs.tuna_ops import OPERATORS, SMOKE_OPERATORS
from repro.hw import TARGETS
from repro.tuna import orchestrator
from repro.tuna.db import ScheduleDatabase

DEFAULT_DB = "experiments/schedule_db.jsonl"


def _csv(s: str) -> List[str]:
    return [x for x in (p.strip() for p in s.split(",")) if x]


def cmd_tune(args: argparse.Namespace) -> int:
    if args.smoke:
        ops = list(SMOKE_OPERATORS)
        targets = ["tpu_v5e"]
        workers = min(args.workers, 2)
        limit = min(args.limit, 256)
    else:
        ops = _csv(args.ops) if args.ops != "all" else list(OPERATORS)
        targets = _csv(args.targets)
        workers, limit = args.workers, args.limit
    for op in ops:
        if op not in OPERATORS:
            print(f"error: unknown operator {op!r}; have {sorted(OPERATORS)}",
                  file=sys.stderr)
            return 2
    for t in targets:
        if t not in TARGETS:
            print(f"error: unknown target {t!r}; have {sorted(TARGETS)}",
                  file=sys.stderr)
            return 2
    db = ScheduleDatabase(args.db)
    jobs = orchestrator.jobs_for(ops, targets, strategy=args.strategy,
                                 limit=limit, seed=args.seed)
    report = orchestrator.run(jobs, db=db, workers=workers,
                              retries=args.retries, verbose=True)
    print(f"[tuna] {len(report.records)}/{len(jobs)} jobs done in "
          f"{report.wall_seconds:.1f}s -> {args.db} ({len(db)} keys)")
    for fail in report.failures:
        print(f"[tuna] FAILED {fail.job.op} @ {fail.job.target} after "
              f"{fail.attempts} attempts:\n{fail.error}", file=sys.stderr)
    return 0 if report.ok else 1


def cmd_query(args: argparse.Namespace) -> int:
    db = ScheduleDatabase(args.db)
    recs = db.query(op=args.op, target=args.target, version=args.version)
    if not recs:
        print("no matching records", file=sys.stderr)
        return 1
    for rec in recs:
        print(json.dumps(dataclasses.asdict(rec), sort_keys=True,
                         default=float))
    return 0


def cmd_compact(args: argparse.Namespace) -> int:
    db = ScheduleDatabase(args.db)
    dropped = db.compact()
    print(f"[tuna] compacted {args.db}: {len(db)} keys kept, "
          f"{dropped} superseded lines dropped")
    return 0


def cmd_export(args: argparse.Namespace) -> int:
    db = ScheduleDatabase(args.db)
    n = db.export(args.out)
    print(f"[tuna] exported {n} records -> {args.out}")
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="repro.tuna", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("tune", help="run tuning jobs into the DB")
    p.add_argument("--db", default=DEFAULT_DB)
    p.add_argument("--ops", default="all",
                   help="comma-separated configs.tuna_ops names, or 'all'")
    p.add_argument("--targets", default="tpu_v5e,cpu_avx2")
    p.add_argument("--strategy", choices=["exhaustive", "es"],
                   default="exhaustive")
    p.add_argument("--workers", type=int, default=4)
    p.add_argument("--retries", type=int, default=2)
    p.add_argument("--limit", type=int, default=1024)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--smoke", action="store_true",
                   help="tiny fixed job set (CI cold-start check)")
    p.set_defaults(fn=cmd_tune)

    p = sub.add_parser("query", help="print best records")
    p.add_argument("--db", default=DEFAULT_DB)
    p.add_argument("--op", default=None, help="exact op signature or prefix")
    p.add_argument("--target", default=None)
    p.add_argument("--version", default=None)
    p.set_defaults(fn=cmd_query)

    p = sub.add_parser("compact", help="drop superseded log lines")
    p.add_argument("--db", default=DEFAULT_DB)
    p.set_defaults(fn=cmd_compact)

    p = sub.add_parser("export", help="dump best records as JSON")
    p.add_argument("--db", default=DEFAULT_DB)
    p.add_argument("--out", default="experiments/schedule_db.json")
    p.set_defaults(fn=cmd_export)
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except BrokenPipeError:
        # downstream head/pager closed the pipe: the unix-normal exit.
        # Re-point stdout at devnull so interpreter shutdown doesn't print
        # a spurious "Exception ignored" on the final flush.
        import os

        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        return 0


if __name__ == "__main__":
    raise SystemExit(main())
