"""Fleet controller daemon — autonomous tuning as a long-running service.

Tuna's cost model needs no target hardware in the loop, so the tuning fleet
is a pure software service; what was missing is the *operator*: today a
human runs ``tune``/``sync``/``snapshot`` by hand. ``FleetController`` is
that operator as a daemon (the AutoTVM tracker/worker split, MITuna's
machine-management interface), keeping the store, snapshots, and serving
hosts converged with no manual steps:

1. **Dispatch** — the (op × target × strategy) job matrix is sharded by
   ``fleet.shard_jobs`` and each shard is handed to a worker (a
   ``python -m repro.tuna tune`` subprocess, or an in-process thread when
   the channel is in-process ``mem://``) under a ``fleet.ShardLease``:
   worker liveness is the heartbeat, the lease deadline bounds how long a
   wedged worker can sit on a shard.
2. **Heal** — a worker that exits without publishing its store (crash) or
   outlives its lease (hang → killed) loses the shard; the controller
   re-dispatches it, up to ``max_attempts`` per shard. Detection reuses
   ``sync``'s crash-skip probe (``fleet.shard_present``: the store
   file/manifest is the commit marker). Because tuning is a pure function
   of the job matrix, a zombie worker finishing late is harmless — its
   records merge idempotently.
3. **Reconcile** — after every change, ``fleet.sync`` merges the shard
   stores, then the controller re-verifies the merge the way
   ``sync --verify`` does: a fresh in-memory merge of the same sources
   must agree with the on-disk store (divergence → gauge + log, corrupt
   source lines → not converged).
4. **Publish** — ``SnapshotManager.ensure``/``publish`` run exactly when
   the merged store or ``COST_MODEL_VERSION`` changed (content-addressed
   no-op otherwise), so serving hosts' ``refresh_default_cache()`` polls
   pick the new snapshot up automatically.
5. **Serve** — a stdlib ``http.server`` endpoint (no new dependencies):
   ``GET /schedule?op=&target=&version=`` answers best-record lookups
   from the live snapshot with the same serialization as
   ``python -m repro.tuna query --json``; ``GET /healthz`` reports
   convergence; ``GET /metrics`` exposes Prometheus text counters/gauges
   (jobs dispatched/done/failed/healed, lease expiries, store record
   count and lag, snapshot age and digest, sync divergence).

Run it: ``python -m repro.tuna controller --db fleet.jsonl --smoke
--num-shards 2 --transport dir:///var/tuna/bucket --publish
dir:///var/tuna/bucket --port 8787``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, List, Optional, Sequence, Set
from urllib.parse import parse_qs, urlparse

from repro.core.cost_model import COST_MODEL_VERSION
from repro.tuna import fleet, orchestrator
from repro.tuna.cache import ScheduleCache, SnapshotManager
from repro.tuna.db import ScheduleDatabase, record_to_dict
from repro.tuna.fleet import ShardLease
from repro.tuna.orchestrator import TuneJob


# -- metrics ---------------------------------------------------------------

class ControllerMetrics:
    """Prometheus-text metrics registry (stdlib only). Counters are plain
    ints mutated under the GIL — same discipline as ``ScheduleCache``'s
    hit/miss counters; gauges are recomputed by the controller before each
    render."""

    SPEC = (
        ("jobs_dispatched_total", "counter",
         "Tuning jobs handed to workers (heal re-dispatches included)."),
        ("jobs_done_total", "counter",
         "Tuning jobs completed by a worker that published its store."),
        ("jobs_failed_total", "counter",
         "Tuning jobs on dispatches that crashed or lost their lease."),
        ("jobs_healed_total", "counter",
         "Tuning jobs re-dispatched after a crashed/expired shard."),
        ("shards_healed_total", "counter",
         "Shards re-dispatched after a crash or lease expiry."),
        ("lease_expiries_total", "counter",
         "Shard leases that expired (worker killed, shard re-dispatched)."),
        ("sync_runs_total", "counter",
         "Reconcile rounds (fleet.sync + merge verification)."),
        ("snapshot_rebuilds_total", "counter",
         "Snapshot ensure() calls that wrote a new versioned artifact."),
        ("snapshot_publishes_total", "counter",
         "Snapshots pushed over the publish transport."),
        ("learned_retrains_total", "counter",
         "Learned-ranker ensure() calls that fitted a new artifact "
         "(store training content or cost-model version changed)."),
        ("learned_publishes_total", "counter",
         "Learned-ranker artifacts pushed over the publish transport."),
        ("rounds_total", "counter", "Controller loop iterations."),
        ("store_records", "gauge",
         "Best-record count of the merged store after the last sync."),
        ("store_lag_seconds", "gauge",
         "Seconds since the newest meta.tuned_at in the merged store "
         "(-1 until a stamped record lands)."),
        ("snapshot_age_seconds", "gauge",
         "Seconds since the published snapshot was built (-1 before the "
         "first snapshot)."),
        ("sync_divergence", "gauge",
         "Best-record divergences between the merged store and a fresh "
         "re-merge of the same sources (0 = merge verified)."),
        ("sync_corrupt_lines", "gauge",
         "Corrupt/torn source lines dropped by the last sync."),
        ("active_leases", "gauge", "Shards currently leased to workers."),
        ("shards_done", "gauge", "Shards whose stores have been published."),
        ("shards_failed", "gauge",
         "Shards given up after max_attempts dispatches."),
        ("shards_total", "gauge", "Fleet width (num_shards)."),
    )

    def __init__(self):
        self._v: Dict[str, float] = {name: 0 for name, _, _ in self.SPEC}

    def inc(self, name: str, n: float = 1) -> None:
        self._v[name] += n

    def set(self, name: str, value: float) -> None:
        self._v[name] = value

    def get(self, name: str) -> float:
        return self._v[name]

    @staticmethod
    def _fmt(v: float) -> str:
        return str(int(v)) if float(v).is_integer() else f"{v:.3f}"

    def render(self, info: Optional[Dict[str, str]] = None) -> str:
        lines: List[str] = []
        for name, kind, help_ in self.SPEC:
            full = f"tuna_{name}"
            lines.append(f"# HELP {full} {help_}")
            lines.append(f"# TYPE {full} {kind}")
            lines.append(f"{full} {self._fmt(self._v[name])}")
        if info:
            labels = ",".join(f'{k}="{v}"' for k, v in sorted(info.items()))
            lines.append("# HELP tuna_snapshot_info Identity of the "
                         "snapshot currently served (digest, cost-model "
                         "version).")
            lines.append("# TYPE tuna_snapshot_info gauge")
            lines.append(f"tuna_snapshot_info{{{labels}}} 1")
        return "\n".join(lines) + "\n"


# -- workers ---------------------------------------------------------------

class SubprocessWorker:
    """A shard worker as a child process (the production mode): the
    ordinary ``python -m repro.tuna tune`` CLI tunes the shard slice and
    pushes/writes its store. Process liveness is the heartbeat."""

    def __init__(self, argv: Sequence[str], env: Optional[Dict] = None):
        self.proc = subprocess.Popen(list(argv), env=env)

    def poll(self) -> Optional[int]:
        return self.proc.poll()

    def kill(self) -> None:
        if self.proc.poll() is not None:
            return
        self.proc.terminate()
        try:
            self.proc.wait(timeout=5)
        except subprocess.TimeoutExpired:
            self.proc.kill()
            try:
                self.proc.wait(timeout=5)
            except subprocess.TimeoutExpired:  # pragma: no cover
                pass

    def describe(self) -> str:
        return f"pid {self.proc.pid}"


class ThreadWorker:
    """A shard worker as an in-process daemon thread — used when the fleet
    channel is in-process (``mem://``) and by tests. ``fn(cancelled)``
    returns truthy/None for success; exceptions report exit code 1.

    Threads cannot be killed: ``kill()`` sets the cooperative ``cancelled``
    event and *abandons* the thread, reporting exit -9. An abandoned worker
    that later finishes anyway only pushes records a re-dispatched worker
    will push identically (tuning is pure), and the merge's total record
    order absorbs duplicates as a no-op."""

    def __init__(self, fn: Callable):
        self.cancelled = threading.Event()
        self._code: Optional[int] = None
        self._killed = False

        def _run():
            try:
                ok = fn(self.cancelled)
                self._code = 0 if ok is None or ok else 2
            except BaseException:  # noqa: BLE001 — worker crash, not ours
                self._code = 1

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def poll(self) -> Optional[int]:
        if self._killed:
            return -9
        if self._thread.is_alive():
            return None
        return self._code if self._code is not None else 1

    def kill(self) -> None:
        self._killed = True
        self.cancelled.set()

    def describe(self) -> str:
        return f"thread {self._thread.name}"


# -- the controller --------------------------------------------------------

@dataclasses.dataclass
class ControllerConfig:
    db: str
    ops: Sequence[str]
    targets: Sequence[str]
    num_shards: int = 2
    strategy: str = "exhaustive"
    limit: int = 256
    seed: int = 0
    transport: Optional[object] = None   # spec string or Transport instance
    snapshot_dir: Optional[str] = None   # default: <db>.snapshots/
    publish: Optional[object] = None     # transport the snapshots go out on
    learned_dir: Optional[str] = None    # retrain + republish the learned
    #   ranker into this directory on store content change (None = off)
    lease_s: float = 300.0
    poll_s: float = 0.5
    max_attempts: int = 3                # dispatches per shard before giving up
    max_workers: int = 2                 # concurrent shard workers
    worker_procs: int = 2                # orchestrator pool inside a worker
    worker_retries: int = 2
    worker_mode: str = "auto"            # auto | process | thread
    inject_crash_shard: Optional[int] = None  # fault injection: this
    #   shard's FIRST dispatch dies before publishing (CI heal check)
    quiet: bool = False


class FleetController:
    """The autonomous tune → heal → sync → snapshot loop (see module
    docstring). Construct, then either call ``step()`` yourself (tests,
    benchmarks) or ``run()`` for the daemon loop; ``start_http`` serves
    the query/health/metrics API from any thread."""

    def __init__(self, cfg: ControllerConfig,
                 jobs: Optional[Sequence[TuneJob]] = None,
                 worker_factory: Optional[Callable] = None):
        if cfg.num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {cfg.num_shards}")
        self.cfg = cfg
        self.jobs = list(jobs) if jobs is not None else orchestrator.jobs_for(
            cfg.ops, cfg.targets, strategy=cfg.strategy, limit=cfg.limit,
            seed=cfg.seed)
        self.transport = None
        if cfg.transport is not None:
            from repro.tuna.transport import resolve_transport

            self.transport = resolve_transport(cfg.transport)
        self.snapshot_dir = cfg.snapshot_dir or os.fspath(cfg.db) + \
            ".snapshots"
        self.manager = SnapshotManager(cfg.db, self.snapshot_dir)
        self.learned_manager = None
        if cfg.learned_dir:
            from repro.tuna.learned import LearnedManager

            self.learned_manager = LearnedManager(cfg.db, cfg.learned_dir)
        self._learned_info = None
        self._published_learned_sha: Optional[str] = None
        self.metrics = ControllerMetrics()
        self.metrics.set("shards_total", cfg.num_shards)
        self.leases: Dict[int, ShardLease] = {}
        self.attempts: Dict[int, int] = {i: 0 for i in range(cfg.num_shards)}
        self.done: Set[int] = set()
        self.given_up: Set[int] = set()
        self.events: List[Dict] = []  # timestamped dispatch/heal/fail log
        self.rounds = 0
        self._worker_factory = worker_factory or self._default_worker
        self._stop = threading.Event()
        self._dirty = True           # store may be ahead of the last sync
        self._last_sync: Optional[fleet.SyncReport] = None
        self._last_sync_clean = False
        self._store_records = 0
        self._last_tuned_at: Optional[float] = None
        self._snapshot_info = None
        self._published_sha: Optional[str] = None
        self._cache: Optional[ScheduleCache] = None
        self._shard_jobs = {
            i: len(fleet.shard_jobs(self.jobs, cfg.num_shards, i))
            for i in range(cfg.num_shards)
        }
        # resume support: shards already published (a previous controller
        # run, or hand-run `tune` hosts) are done — the manifest/store file
        # is the commit marker, exactly as sync sees it
        for sid in range(cfg.num_shards):
            if fleet.shard_present(cfg.db, sid, transport=self.transport):
                self.done.add(sid)
                self._event("resumed", sid, "store already present")

    # -- logging / events ------------------------------------------------

    def _log(self, msg: str) -> None:
        if not self.cfg.quiet:
            print(f"[controller] {msg}", flush=True)

    def _event(self, kind: str, shard: int, detail: str = "") -> None:
        self.events.append({"t": time.time(), "event": kind, "shard": shard,
                            "detail": detail})

    # -- worker dispatch --------------------------------------------------

    def _thread_mode(self) -> bool:
        if self.cfg.worker_mode != "auto":
            return self.cfg.worker_mode == "thread"
        from repro.tuna.transport import MemoryTransport

        return isinstance(self.transport, MemoryTransport)

    def _worker_env(self) -> Dict[str, str]:
        """Child env with the ``repro`` package importable even when the
        controller was launched from somewhere else."""
        import repro

        # repro may be a namespace package (__file__ is None): locate the
        # src dir from __path__ instead
        pkg_dir = (os.path.dirname(repro.__file__)
                   if getattr(repro, "__file__", None)
                   else list(repro.__path__)[0])
        src = os.path.dirname(os.path.abspath(pkg_dir))
        env = dict(os.environ)
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        return env

    def _worker_argv(self, shard_id: int) -> List[str]:
        cfg = self.cfg
        argv = [sys.executable, "-m", "repro.tuna", "tune",
                "--db", os.fspath(cfg.db),
                "--num-shards", str(cfg.num_shards),
                "--shard-id", str(shard_id), "--as-shard",
                "--ops", ",".join(cfg.ops),
                "--targets", ",".join(cfg.targets),
                "--strategy", cfg.strategy,
                "--limit", str(cfg.limit), "--seed", str(cfg.seed),
                "--workers", str(cfg.worker_procs),
                "--retries", str(cfg.worker_retries)]
        if self.transport is not None:
            argv += ["--transport", self.transport.describe()]
        return argv

    def _default_worker(self, shard_id: int, attempt: int):
        if self.cfg.inject_crash_shard == shard_id and attempt == 1:
            # fault injection: die without publishing the shard store —
            # indistinguishable from a mid-shard worker crash
            if self._thread_mode():
                def _crash(cancelled):
                    raise RuntimeError("injected worker crash")
                return ThreadWorker(_crash)
            return SubprocessWorker(
                [sys.executable, "-c", "raise SystemExit(42)"],
                env=self._worker_env())
        if self._thread_mode():
            cfg = self.cfg

            def _run(cancelled):
                run = fleet.run_shard(
                    self.jobs, cfg.num_shards, shard_id, cfg.db,
                    transport=self.transport, workers=cfg.worker_procs,
                    retries=cfg.worker_retries)
                return run.ok
            return ThreadWorker(_run)
        return SubprocessWorker(self._worker_argv(shard_id),
                                env=self._worker_env())

    def _pending(self) -> List[int]:
        return [i for i in range(self.cfg.num_shards)
                if i not in self.done and i not in self.leases
                and i not in self.given_up]

    def _dispatch(self, shard_id: int) -> None:
        self.attempts[shard_id] += 1
        attempt = self.attempts[shard_id]
        njobs = self._shard_jobs[shard_id]
        if attempt > 1:  # healing a crashed/expired shard
            self.metrics.inc("shards_healed_total")
            self.metrics.inc("jobs_healed_total", njobs)
            self._event("healed", shard_id, f"re-dispatch attempt {attempt}")
        worker = self._worker_factory(shard_id, attempt)
        self.leases[shard_id] = ShardLease(
            shard_id=shard_id, jobs=njobs, granted_at=time.monotonic(),
            lease_s=self.cfg.lease_s, attempt=attempt, worker=worker)
        self.metrics.inc("jobs_dispatched_total", njobs)
        self._event("dispatched", shard_id, f"attempt {attempt}")
        self._log(f"shard {shard_id}: dispatched {njobs} jobs to "
                  f"{worker.describe()} (attempt {attempt}, lease "
                  f"{self.cfg.lease_s:.0f}s)")

    def _lease_failed(self, shard_id: int, reason: str) -> None:
        lease = self.leases.pop(shard_id)
        self.metrics.inc("jobs_failed_total", lease.jobs)
        self._event("failed", shard_id, reason)
        if self.attempts[shard_id] >= self.cfg.max_attempts:
            self.given_up.add(shard_id)
            self._log(f"shard {shard_id}: GIVING UP after "
                      f"{self.attempts[shard_id]} attempts ({reason})")
        else:
            self._log(f"shard {shard_id}: {reason}; will re-dispatch")

    # -- the control loop -------------------------------------------------

    def step(self) -> None:
        """One controller round: heartbeat the leases, reap finished and
        failed workers, dispatch pending shards, reconcile + snapshot once
        the fleet is quiescent."""
        self.rounds += 1
        self.metrics.inc("rounds_total")
        now = time.monotonic()
        for sid in sorted(self.leases):
            lease = self.leases[sid]
            code = lease.worker.poll()
            if code is None:
                if lease.expired(now):
                    self.metrics.inc("lease_expiries_total")
                    lease.worker.kill()
                    self._lease_failed(
                        sid, f"lease expired after {lease.lease_s:.1f}s "
                             f"(worker killed)")
                else:
                    lease.heartbeat(now)
                continue
            if code == 0 and fleet.shard_present(self.cfg.db, sid,
                                                 transport=self.transport):
                del self.leases[sid]
                self.done.add(sid)
                self._dirty = True
                self.metrics.inc("jobs_done_total", lease.jobs)
                self._event("done", sid, f"attempt {lease.attempt}")
                self._log(f"shard {sid}: done ({lease.jobs} jobs, attempt "
                          f"{lease.attempt})")
            elif code == 0:
                self._lease_failed(sid, "worker exited 0 without "
                                        "publishing its store")
            else:
                self._lease_failed(sid, f"worker crashed (exit {code})")
        for sid in self._pending():
            if len(self.leases) >= self.cfg.max_workers:
                break
            self._dispatch(sid)
        if not self.leases and not self._pending() and self._dirty:
            self.reconcile()
        self.metrics.set("active_leases", len(self.leases))
        self.metrics.set("shards_done", len(self.done))
        self.metrics.set("shards_failed", len(self.given_up))

    def reconcile(self) -> fleet.SyncReport:
        """``sync`` the shard stores into the base store, then re-verify
        the merge the way ``sync --verify`` does: a fresh in-memory merge
        of the same sources must produce the same best-record set (the
        total record order makes this deterministic — any divergence is a
        real bug or torn data, surfaced as a gauge and in the log)."""
        rep = fleet.sync(self.cfg.db, self.cfg.num_shards,
                         transport=self.transport)
        self.metrics.inc("sync_runs_total")
        scratch = ScheduleDatabase(None)
        for src in rep.absorbed:
            scratch.merge(src, provenance=True)
        div = fleet.divergence(rep.db, scratch, "store", "fresh-merge")
        for msg in div[:10]:
            self._log(f"SYNC DIVERGENCE: {msg}")
        self.metrics.set("sync_divergence", len(div))
        self.metrics.set("sync_corrupt_lines", rep.corrupt_lines)
        self.metrics.set("store_records", rep.keys)
        self._store_records = rep.keys
        self._last_tuned_at = rep.db.last_tuned_at()
        self._last_sync = rep
        self._last_sync_clean = (not div and not rep.corrupt_lines
                                 and not rep.skipped)
        self._dirty = False
        self._log(f"synced {rep.keys} keys from "
                  f"{self.cfg.num_shards - len(rep.skipped)}/"
                  f"{self.cfg.num_shards} shards "
                  f"(divergence={len(div)}, corrupt={rep.corrupt_lines})")
        self.ensure_snapshot()
        return rep

    def ensure_snapshot(self) -> None:
        """Bring the snapshot directory (and the publish channel, when
        configured) up to date with the store. Content-addressing inside
        ``SnapshotManager.ensure`` makes this exact: a new artifact is
        written/pushed iff the record payload or ``COST_MODEL_VERSION``
        changed."""
        info = self.manager.ensure()
        self._snapshot_info = info
        if info.rebuilt:
            self.metrics.inc("snapshot_rebuilds_total")
            self._log(f"snapshot rebuilt: {info.name} ({info.count} records)")
        if self.cfg.publish is not None and \
                info.sha1 != self._published_sha:
            self.manager.publish(self.cfg.publish, info=info)
            self._published_sha = info.sha1
            self.metrics.inc("snapshot_publishes_total")
            self._log(f"snapshot published: {info.name}")
        if self._cache is None or self._cache.sha1 != info.sha1:
            self._cache = ScheduleCache.load(info.path)
        self.ensure_learned()

    def ensure_learned(self) -> None:
        """Bring the learned-ranker artifact up to date with the store —
        the same ensure-on-change contract as snapshots: the ``latest``
        pointer records the sha1 of the training rows the model was fitted
        from, so ``LearnedManager.ensure`` retrains exactly when the
        store's training content (or the cost-model version) changed. A
        store too small to train on is not an error — it just isn't time
        yet."""
        if self.learned_manager is None:
            return
        try:
            info = self.learned_manager.ensure()
        except ValueError as e:
            self._log(f"learned ranker not trainable yet: {e}")
            return
        self._learned_info = info
        if info.retrained:
            self.metrics.inc("learned_retrains_total")
            self._log(f"learned ranker retrained: {info.name} "
                      f"({info.samples} samples)")
        if self.cfg.publish is not None and \
                info.sha1 != self._published_learned_sha:
            self.learned_manager.publish(self.cfg.publish, info=info)
            self._published_learned_sha = info.sha1
            self.metrics.inc("learned_publishes_total")
            self._log(f"learned ranker published: {info.name}")

    @property
    def converged(self) -> bool:
        """Every shard tuned and published, the merged store verified
        clean, and the snapshot current — the acceptance state."""
        return (len(self.done) == self.cfg.num_shards
                and not self.leases and not self.given_up
                and not self._dirty and self._last_sync_clean
                and self._snapshot_info is not None)

    @property
    def wedged(self) -> bool:
        """Nothing left to dispatch but shards were given up — the fleet
        cannot converge without operator help."""
        return bool(self.given_up) and not self.leases \
            and not self._pending()

    def stop(self) -> None:
        self._stop.set()

    def run(self, max_rounds: Optional[int] = None,
            exit_when_converged: bool = False) -> int:
        """The daemon loop. Returns 0 when converged (or stopped cleanly
        with no given-up shards), 1 otherwise. With
        ``exit_when_converged`` the loop ends at the first converged (or
        wedged) round; otherwise it keeps watching — a store change (new
        records synced in by hand, a re-pushed shard) re-triggers
        reconcile + republish."""
        while not self._stop.is_set():
            self.step()
            if exit_when_converged and (self.converged or self.wedged):
                break
            if max_rounds is not None and self.rounds >= max_rounds:
                break
            self._stop.wait(self.cfg.poll_s)
        return 0 if not self.given_up else 1

    # -- introspection (the HTTP surface) ---------------------------------

    def health(self) -> Dict:
        info = self._snapshot_info
        return {
            "status": "degraded" if self.given_up else "ok",
            "converged": self.converged,
            "rounds": self.rounds,
            "shards": {
                "total": self.cfg.num_shards,
                "done": len(self.done),
                "leased": sorted(self.leases),
                "failed": sorted(self.given_up),
            },
            "store_records": self._store_records,
            "snapshot": None if info is None else {
                "name": info.name, "sha1": info.sha1,
                "count": info.count, "built_at": info.built_at,
            },
        }

    def metrics_text(self) -> str:
        now = time.time()
        lag = -1.0 if self._last_tuned_at is None \
            else max(0.0, now - self._last_tuned_at)
        self.metrics.set("store_lag_seconds", round(lag, 3))
        built = getattr(self._snapshot_info, "built_at", None)
        age = -1.0 if built is None else max(0.0, now - built)
        self.metrics.set("snapshot_age_seconds", round(age, 3))
        info = None
        if self._snapshot_info is not None:
            info = {"sha1": self._snapshot_info.sha1,
                    "cost_model_version": COST_MODEL_VERSION}
        return self.metrics.render(info=info)

    def schedule_lookup(self, op: Optional[str] = None,
                        target: Optional[str] = None,
                        version: Optional[str] = None) -> List[Dict]:
        """Best-record lookup from the live snapshot, serialized with the
        same ``record_to_dict`` as ``query --json`` — the CLI and the HTTP
        API can never disagree. Raises ``LookupError`` before the first
        snapshot exists."""
        if self._cache is None:
            raise LookupError("no snapshot published yet")
        recs = self._cache.query(op=op, target=target, version=version)
        return [record_to_dict(r) for r in recs]


# -- HTTP API --------------------------------------------------------------

class _ControllerServer(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True
    controller: FleetController = None


class _Handler(BaseHTTPRequestHandler):
    server_version = "tuna-controller/1"

    def log_message(self, *args):  # the controller does its own logging
        pass

    def _send(self, code: int, body: str, ctype: str) -> None:
        data = body.encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _json(self, code: int, obj: Dict) -> None:
        self._send(code, json.dumps(obj, sort_keys=True, default=float)
                   + "\n", "application/json")

    def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler protocol
        ctl: FleetController = self.server.controller
        url = urlparse(self.path)
        if url.path == "/healthz":
            self._json(200, ctl.health())
        elif url.path == "/metrics":
            self._send(200, ctl.metrics_text(),
                       "text/plain; version=0.0.4; charset=utf-8")
        elif url.path == "/schedule":
            q = parse_qs(url.query)

            def _arg(name):
                vals = q.get(name)
                return vals[0] if vals else None

            try:
                records = ctl.schedule_lookup(op=_arg("op"),
                                              target=_arg("target"),
                                              version=_arg("version"))
            except LookupError as e:
                self._json(503, {"error": str(e)})
                return
            if not records:
                self._json(404, {"error": "no matching records"})
                return
            cache = ctl._cache
            self._json(200, {
                "count": len(records),
                "snapshot_sha1": cache.sha1,
                "built_at": cache.built_at,
                "cost_model_version": cache.cost_model_version,
                "records": records,
            })
        else:
            self._json(404, {"error": f"no route {url.path!r}; have "
                                      f"/schedule /healthz /metrics"})


def start_http(controller: FleetController, host: str = "127.0.0.1",
               port: int = 0) -> _ControllerServer:
    """Serve the controller's API on a daemon thread; returns the server
    (``server.server_address`` has the bound port; call ``shutdown()`` +
    ``server_close()`` to stop)."""
    server = _ControllerServer((host, port), _Handler)
    server.controller = controller
    thread = threading.Thread(target=server.serve_forever, daemon=True,
                              name="tuna-controller-http")
    thread.start()
    return server
