"""Persistent schedule database — the MITuna-style service substrate.

Tuna schedules are derived *statically*, so a result is a pure function of
``(operator signature, target, cost-model version)`` and can be persisted and
shared across processes/hosts instead of recomputed per process (the same
observation behind AutoTVM tuning logs and TLP's record datasets).

Storage is an **append-only JSONL** file, schema ``cm1`` — one record per
line, formalising the ad-hoc ``experiments/schedule_db.jsonl`` format:

    {
      "op":          "matmul[K=256,M=256,N=256,dtype_bytes=2]",
      "target":      "tpu_v5e",
      "version":     "cm1",                 # cost-model version (see
                                            # repro.core.cost_model)
      "config":      {"bm": 256, ...},      # winning schedule knobs
      "score":       2.82e-06,              # predicted cost (lower = faster)
      "evaluations": 48,                    # cost-model calls spent finding it
      "meta":        {"strategy": "exhaustive", "default_score": ...}
    }

Appends are single ``write`` calls on an ``O_APPEND`` handle (atomic on
POSIX); compaction rewrites via temp-file + ``os.replace`` so readers never
observe a half-written store. The in-memory index keeps the *best* (lowest
score) record per key; the log keeps full history until ``compact()``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

try:  # POSIX cross-process lock; degrades to thread-only elsewhere
    import fcntl

    def _flock(f) -> None:
        fcntl.flock(f.fileno(), fcntl.LOCK_EX)
except ImportError:  # pragma: no cover
    def _flock(f) -> None:
        pass

from repro.core.cost_model import COST_MODEL_VERSION

SCHEMA = "cm1"

Key = Tuple[str, str, str]  # (op signature, target name, cost-model version)

# Meta keys that are *bookkeeping*, not tuning content: which shard a record
# travelled through (``provenance``) and when it was tuned (``tuned_at``).
# They are stripped from the canonical record form (tie-breaks, divergence
# checks): two hosts tuning the same key at different wall-clock times must
# still converge on byte-identical winners, or fleet merges stop being
# order-independent and ``sync --verify`` flags phantom divergence.
TUNED_AT_KEY = "tuned_at"
BOOKKEEPING_META = frozenset({"provenance", TUNED_AT_KEY})


def strip_bookkeeping(meta: Dict) -> Dict:
    """``meta`` without the bookkeeping keys (see ``BOOKKEEPING_META``)."""
    return {k: v for k, v in meta.items() if k not in BOOKKEEPING_META}


def stamp_tuned_at(meta: Optional[Dict] = None,
                   now: Optional[float] = None) -> Dict:
    """Return ``meta`` with a wall-clock ``tuned_at`` stamp (seconds since
    the epoch, ms precision) added when absent. The stamp is what the fleet
    controller's ``store_lag_seconds`` gauge is computed from; records
    without it (pre-stamp stores) still load and merge — they just don't
    move the lag gauge."""
    meta = dict(meta or {})
    if TUNED_AT_KEY not in meta:
        meta[TUNED_AT_KEY] = round(time.time() if now is None else now, 3)
    return meta


@dataclasses.dataclass(frozen=True)
class ScheduleRecord:
    op: str
    target: str
    config: Dict
    score: float
    evaluations: int = 0
    meta: Dict = dataclasses.field(default_factory=dict)
    version: str = COST_MODEL_VERSION

    @property
    def key(self) -> Key:
        return (self.op, self.target, self.version)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True,
                          default=float)

    @classmethod
    def from_json(cls, line: str) -> "ScheduleRecord":
        return cls.from_dict(json.loads(line))

    @classmethod
    def from_dict(cls, obj: Dict) -> "ScheduleRecord":
        return cls(
            op=str(obj["op"]),
            target=str(obj["target"]),
            config=dict(obj["config"]),
            score=float(obj["score"]),
            evaluations=int(obj.get("evaluations", 0)),
            meta=dict(obj.get("meta", {})),
            version=str(obj.get("version", COST_MODEL_VERSION)),
        )


def query_index(index: Dict[Key, ScheduleRecord], op: Optional[str] = None,
                target: Optional[str] = None,
                version: Optional[str] = None) -> List[ScheduleRecord]:
    """Filter a best-record index (shared by ``ScheduleDatabase.query`` and
    ``ScheduleCache.query`` so the two stores can never diverge): ``op``
    matches exactly or as a prefix (``matmul`` matches every matmul
    shape), ``target``/``version`` match exactly."""
    out = []
    for key in sorted(index):
        rec = index[key]
        if op is not None and not (rec.op == op or rec.op.startswith(op)):
            continue
        if target is not None and rec.target != target:
            continue
        if version is not None and rec.version != version:
            continue
        out.append(rec)
    return out


def record_to_dict(rec: ScheduleRecord) -> Dict:
    """The one record serialization shared by ``query --json``, ``export``,
    and the fleet controller's ``/schedule`` endpoint — operators reading
    the CLI and services reading the HTTP API can never disagree on field
    names or types."""
    obj = dataclasses.asdict(rec)
    obj["score"] = float(rec.score)
    return obj


def _canonical(rec: ScheduleRecord) -> str:
    """Canonical record JSON with merge bookkeeping stripped: the
    provenance stamp says which shard a record travelled through and
    ``tuned_at`` when, neither of which must ever decide who wins a tie
    (a fleet-merged store and a single-process store would otherwise pick
    different winners)."""
    obj = dataclasses.asdict(rec)
    obj["meta"] = strip_bookkeeping(obj["meta"])
    return json.dumps(obj, sort_keys=True, default=float)


def record_beats(rec: ScheduleRecord, cur: ScheduleRecord) -> bool:
    """Preference order between same-key records: lower score wins; exact
    score ties break on the canonical (provenance-stripped) record JSON,
    and a canonical tie keeps the incumbent. A total order over canonical
    records is what makes merges commutative, associative, and idempotent
    — the winner for a key is independent of arrival order, so fleet
    shards can sync in any order and every host converges on the same
    store."""
    if rec.score != cur.score:
        return rec.score < cur.score
    return _canonical(rec) < _canonical(cur)


class ScheduleDatabase:
    """JSONL-backed schedule store with an in-memory best-record index.

    ``path=None`` gives a purely in-memory database (tests, dry runs). A
    path that does not exist yet is created on first ``add``.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = os.fspath(path) if path is not None else None
        self._lock = threading.Lock()
        self._best: Dict[Key, ScheduleRecord] = {}
        self.lines_read = 0
        self.corrupt_lines = 0
        if self.path and os.path.exists(self.path):
            for rec in self._iter_file(self.path):
                self._absorb(rec)

    # -- loading ---------------------------------------------------------

    def _iter_file(self, path: str, lock: bool = False,
                   ) -> Iterator[ScheduleRecord]:
        """Parse a store file. ``lock=True`` takes the cross-process flock
        before reading: appends are single writes flushed under that lock,
        so a locked read can never observe the torn tail of an in-flight
        writer — without it a half-written final line silently counts as
        corrupt and the record is dropped."""
        with open(path, "r", encoding="utf-8") as f:
            if lock:
                _flock(f)
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = ScheduleRecord.from_json(line)
                except (ValueError, KeyError, TypeError):
                    self.corrupt_lines += 1
                    continue
                self.lines_read += 1
                yield rec

    def _absorb(self, rec: ScheduleRecord) -> bool:
        """Index ``rec``; True iff it is a new key or beats the incumbent."""
        cur = self._best.get(rec.key)
        if cur is None or record_beats(rec, cur):
            self._best[rec.key] = rec
            return True
        return False

    # -- writes ----------------------------------------------------------

    def add(self, rec: ScheduleRecord, persist: bool = True) -> bool:
        """Append ``rec`` to the log and index it. Returns True iff the
        record became the best for its key."""
        with self._lock:
            improved = self._absorb(rec)
            if persist and self.path:
                d = os.path.dirname(self.path)
                if d:
                    os.makedirs(d, exist_ok=True)
                self._append_locked(rec.to_json() + "\n")
        return improved

    def _append_locked(self, line: str, max_retries: int = 50) -> None:
        """Append under the cross-process lock; if a concurrent ``compact``
        replaced the log while we waited (our fd then points at the orphaned
        inode), reopen against the new file and retry. Retries are bounded:
        a store path that *keeps* vanishing (the store directory deleted
        mid-fleet, a job scrubbing the workdir) is an operational failure
        that must surface, not an infinite busy-loop."""
        for _ in range(max_retries):
            with open(self.path, "a", encoding="utf-8") as f:
                _flock(f)
                try:
                    cur_ino = os.stat(self.path).st_ino
                except FileNotFoundError:
                    continue
                if os.fstat(f.fileno()).st_ino != cur_ino:
                    continue
                f.write(line)
                return
        raise RuntimeError(
            f"{self.path}: gave up appending after {max_retries} attempts — "
            f"the store file keeps vanishing or being replaced out from "
            f"under the writer (was the store directory removed while the "
            f"fleet is running?)")

    def merge(self, other_path: str, provenance=None,
              lock_source: bool = True) -> int:
        """Absorb another store's records; persists only the improving ones
        (the log stays append-only, compaction prunes). Conflicts resolve by
        the total record order (cost-model version is part of the key; lower
        score wins, ties break canonically). ``provenance=True`` stamps
        absorbed records with ``meta["provenance"] = <source basename>`` (a
        string label is used verbatim) so a merged store says which shard
        each winner came from. Returns how many records improved/extended
        this store.

        The source is snapshotted under its cross-process flock (then the
        lock is released before any write, so two hosts merging toward each
        other cannot deadlock): a shard writer mid-append either finishes
        its line before we read or hasn't started it — its record is merged
        or deferred to the next sync, never torn and miscounted as corrupt.
        Corrupt lines that *do* remain accumulate on ``corrupt_lines``;
        ``sync`` reports the per-source delta."""
        if provenance is True:
            provenance = os.path.basename(os.fspath(other_path))
        absorbed = 0
        for rec in list(self._iter_file(other_path, lock=lock_source)):
            if provenance:
                rec = dataclasses.replace(
                    rec, meta={**rec.meta, "provenance": provenance})
            if self._would_improve(rec):
                self.add(rec, persist=True)
                absorbed += 1
        return absorbed

    def merge_all(self, paths: Sequence[str], provenance=True,
                  ) -> Tuple[Dict[str, int], Dict[str, int]]:
        """Merge several shard stores; returns ``(absorbed counts,
        corrupt-line counts)`` per path — a non-zero corrupt count means
        lines were dropped and the merge is *not* lossless."""
        stats: Dict[str, int] = {}
        corrupt: Dict[str, int] = {}
        for p in paths:
            before = self.corrupt_lines
            stats[os.fspath(p)] = self.merge(p, provenance=provenance)
            corrupt[os.fspath(p)] = self.corrupt_lines - before
        return stats, corrupt

    @classmethod
    def sync(cls, dst_path: str, shard_paths: Sequence[str],
             provenance=True, compact: bool = True,
             ) -> Tuple["ScheduleDatabase", Dict[str, int], Dict[str, int]]:
        """Reconcile per-shard stores into ``dst_path`` (the fleet read side
        of ``repro.tuna.fleet``): open the base store, absorb every shard,
        optionally compact. Returns ``(merged db, absorbed counts,
        corrupt-line counts per source)``."""
        db = cls(dst_path)
        stats, corrupt = db.merge_all(shard_paths, provenance=provenance)
        if compact:
            db.compact()
        return db, stats, corrupt

    def _would_improve(self, rec: ScheduleRecord) -> bool:
        cur = self._best.get(rec.key)
        return cur is None or record_beats(rec, cur)

    def compact(self) -> int:
        """Rewrite the log keeping only the best record per key (atomic
        replace). Holds the cross-process lock and re-reads the log first,
        so records appended by other processes since our load are absorbed
        rather than clobbered. Returns the number of log lines dropped
        (superseded duplicates + corrupt lines)."""
        if not self.path:
            return 0
        with self._lock:
            d = os.path.dirname(self.path) or "."
            os.makedirs(d, exist_ok=True)
            while True:
                with open(self.path, "a+", encoding="utf-8") as f:
                    _flock(f)
                    if os.fstat(f.fileno()).st_ino != os.stat(self.path).st_ino:
                        continue  # lost a race with another compact; reopen
                    f.seek(0)
                    before = 0
                    for line in f:
                        if not line.strip():
                            continue
                        before += 1
                        try:
                            self._absorb(ScheduleRecord.from_json(line))
                        except (ValueError, KeyError, TypeError):
                            pass  # corrupt line: healed by the rewrite
                    records = [self._best[k] for k in sorted(self._best)]
                    fd, tmp = tempfile.mkstemp(dir=d, suffix=".jsonl.tmp")
                    try:
                        with os.fdopen(fd, "w", encoding="utf-8") as out:
                            for rec in records:
                                out.write(rec.to_json() + "\n")
                        os.replace(tmp, self.path)
                    except BaseException:
                        if os.path.exists(tmp):
                            os.unlink(tmp)
                        raise
                    return before - len(records)

    # -- queries ---------------------------------------------------------

    def best(self, op: str, target: str,
             version: str = COST_MODEL_VERSION) -> Optional[ScheduleRecord]:
        return self._best.get((op, target, version))

    def query(self, op: Optional[str] = None, target: Optional[str] = None,
              version: Optional[str] = None) -> List[ScheduleRecord]:
        """Best records matching the filters; ``op`` matches exactly or as a
        prefix (so ``matmul`` matches every matmul shape)."""
        return query_index(self._best, op=op, target=target, version=version)

    def records(self) -> List[ScheduleRecord]:
        return [self._best[k] for k in sorted(self._best)]

    def last_tuned_at(self) -> Optional[float]:
        """Newest ``meta.tuned_at`` stamp across the best records — what
        the controller's ``store_lag_seconds`` gauge measures. ``None``
        when no record carries the stamp (pre-stamp stores)."""
        stamps = [r.meta[TUNED_AT_KEY] for r in self._best.values()
                  if isinstance(r.meta.get(TUNED_AT_KEY), (int, float))]
        return max(stamps) if stamps else None

    def export(self, out_path: str) -> int:
        """Write the best records as a JSON array (for dashboards / diffing);
        returns the record count."""
        records = [record_to_dict(r) for r in self.records()]
        d = os.path.dirname(out_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(out_path, "w", encoding="utf-8") as f:
            json.dump(records, f, indent=2, sort_keys=True, default=float)
            f.write("\n")
        return len(records)

    def __len__(self) -> int:
        return len(self._best)

    def __contains__(self, key: Key) -> bool:
        return key in self._best
