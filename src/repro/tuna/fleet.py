"""Distributed tuning fleet — shard the job matrix, tune, reconcile.

Tuna results are pure functions of (op signature, target, cost-model
version): there is no device in the tuning loop, so the MITuna-style fleet
split collapses to *pure bookkeeping*. ``shard_jobs`` deterministically
partitions the (operator × target × strategy) job matrix by hashing each
job's canonical form — shards are disjoint, covering, and stable across
runs and hosts, so re-running a shard is idempotent and any host can own
any shard id. Each shard tunes through the ordinary orchestrator into its
own store (``<base>.shardNN.jsonl``); ``sync`` reconciles shard stores into
the base store whenever they become reachable, resolving conflicts by the
total record order (cost-model version is part of the key, then best
score) and stamping per-shard provenance into ``meta``. A crashed shard
simply stays missing until its host re-runs it — sync skips absent stores
and reports them.

Shard stores reach the sync host either over a shared filesystem (the
default: ``sync`` globs ``<base>.shardNN.jsonl`` next to the base store)
or over a ``repro.tuna.transport`` channel: ``run_shard(...,
transport=...)`` pushes the finished shard store (manifest + sha1), and
``sync(..., transport=...)`` pulls every shard the channel has into a
staging directory with integrity verification before merging — no shared
base directory between shard writers and the sync host.

Workflow (also exposed by ``python -m repro.tuna``):

    jobs = orchestrator.jobs_for(ops, targets)     # the shared matrix
    # on host i of N (no shared fs needed with a transport):
    fleet.run_shard(jobs, N, i, base, transport=t) # tune + push
    # on any host that can reach the channel:
    fleet.sync(base, N, transport=t)               # pull + merge
    SnapshotManager(base, out_dir).publish(t)      # versioned snapshot
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Dict, Iterable, List, Optional, Sequence

from repro.tuna import orchestrator
from repro.tuna.db import ScheduleDatabase, ScheduleRecord, strip_bookkeeping
from repro.tuna.orchestrator import TuneJob

PROVENANCE_KEY = "provenance"


# -- deterministic sharding ----------------------------------------------

def job_fingerprint(job: TuneJob) -> str:
    """Stable content hash of a job (all fields, canonical JSON) — the
    same job hashes identically on every host and every run."""
    blob = json.dumps(dataclasses.asdict(job), sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()


def shard_of(job: TuneJob, num_shards: int) -> int:
    return int(job_fingerprint(job), 16) % num_shards


def shard_jobs(jobs: Sequence[TuneJob], num_shards: int,
               shard_id: int) -> List[TuneJob]:
    """The subset of ``jobs`` owned by ``shard_id``. Partitions are
    disjoint and covering by construction (every job hashes to exactly one
    shard) and independent of the order jobs are listed in."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if not 0 <= shard_id < num_shards:
        raise ValueError(
            f"shard_id must be in [0, {num_shards}), got {shard_id}")
    return [j for j in jobs if shard_of(j, num_shards) == shard_id]


def shard_store_path(base_path: str, shard_id: int) -> str:
    """Per-shard store path derived from the base store path:
    ``db.jsonl`` -> ``db.shard03.jsonl`` (derivation is shared by tune and
    sync, so hosts never have to agree on anything but base + shard id)."""
    root, ext = os.path.splitext(os.fspath(base_path))
    return f"{root}.shard{shard_id:02d}{ext or '.jsonl'}"


# -- running shards -------------------------------------------------------

@dataclasses.dataclass
class ShardRun:
    shard_id: int
    store_path: str
    jobs: int
    report: orchestrator.RunReport
    pushed: Optional[object] = None  # transport Manifest when shipped

    @property
    def ok(self) -> bool:
        return self.report.ok


@dataclasses.dataclass
class FleetReport:
    shards: List[ShardRun]

    @property
    def ok(self) -> bool:
        return all(s.ok for s in self.shards)

    @property
    def records(self) -> List[ScheduleRecord]:
        return [r for s in self.shards for r in s.report.records]


def touch_store(path: str) -> str:
    """Create an empty store file if absent. A shard whose slice of the
    matrix happens to be empty must still leave a store behind — sync
    distinguishes 'shard finished with nothing to do' (empty file) from
    'shard crashed / hasn't run' (no file)."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    open(path, "a", encoding="utf-8").close()
    return path


def shard_object_name(base_path: str, shard_id: int) -> str:
    """Host-independent transport object name for a shard store: the
    basename of the shard store path, so pushing and pulling hosts only
    have to agree on the base store *name*, never on directory layout."""
    return os.path.basename(shard_store_path(base_path, shard_id))


def shard_present(base_path: str, shard_id: int, transport=None) -> bool:
    """The crash-skip probe shared by ``sync`` and the fleet controller:
    a shard's work is *present* when its store file exists (shared-fs
    fleet) or its store object + manifest are in the channel (transport
    fleet — the manifest is the commit marker, so a mid-push crash still
    counts as absent). A shard that is not present has crashed or hasn't
    run; the controller re-dispatches it, ``sync`` skips it."""
    if transport is not None:
        from repro.tuna.transport import resolve_transport

        return resolve_transport(transport).exists(
            shard_object_name(base_path, shard_id))
    return os.path.exists(shard_store_path(base_path, shard_id))


def missing_shards(base_path: str, num_shards: int,
                   transport=None) -> List[int]:
    """Shard ids whose stores have not arrived yet (crashed / not run) —
    ``shard_present`` over the whole fleet."""
    return [i for i in range(num_shards)
            if not shard_present(base_path, i, transport=transport)]


# -- leases ----------------------------------------------------------------

@dataclasses.dataclass
class ShardLease:
    """A dispatched shard's liveness contract with the controller.

    The worker holds the lease from ``granted_at`` until ``deadline``;
    liveness checks (``heartbeat``) renew ``last_heartbeat`` but never the
    deadline — a worker that outlives its lease is presumed wedged and its
    shard is re-dispatched. Because tuning is a pure function of
    (job matrix, shard id), a zombie worker that later finishes anyway is
    harmless: it pushes byte-equivalent records and the merge's total
    order makes absorbing them a no-op."""

    shard_id: int
    jobs: int                 # matrix jobs covered by this dispatch
    granted_at: float         # time.monotonic()
    lease_s: float
    attempt: int = 1          # 1 = first dispatch, >1 = heal re-dispatch
    worker: object = None     # controller-owned handle (poll()/kill())
    last_heartbeat: float = 0.0

    def __post_init__(self):
        if not self.last_heartbeat:
            self.last_heartbeat = self.granted_at

    @property
    def deadline(self) -> float:
        return self.granted_at + self.lease_s

    def heartbeat(self, now: Optional[float] = None) -> None:
        self.last_heartbeat = time.monotonic() if now is None else now

    def expired(self, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        return now > self.deadline


def run_shard(jobs: Sequence[TuneJob], num_shards: int, shard_id: int,
              base_path: str, transport=None, **run_kwargs) -> ShardRun:
    """Tune this shard's slice of the matrix into its own store (the
    existing orchestrator does the work; extra kwargs pass through). With
    a ``transport`` (spec or instance), the finished store is pushed —
    manifest, sha1, record count — so the sync host needs no filesystem
    view of this host at all."""
    mine = shard_jobs(jobs, num_shards, shard_id)
    store = ScheduleDatabase(touch_store(shard_store_path(base_path,
                                                          shard_id)))
    report = orchestrator.run(mine, db=store, **run_kwargs)
    pushed = None
    if transport is not None:
        from repro.tuna.transport import resolve_transport

        pushed = resolve_transport(transport).push(
            store.path, shard_object_name(base_path, shard_id))
    return ShardRun(shard_id, store.path, len(mine), report, pushed)


def run_fleet(jobs: Sequence[TuneJob], num_shards: int, base_path: str,
              shard_ids: Optional[Iterable[int]] = None, transport=None,
              **run_kwargs) -> FleetReport:
    """Run shards in one process (tests, single-host fleets); on a real
    fleet each host calls ``run_shard`` for the ids it owns."""
    ids = range(num_shards) if shard_ids is None else shard_ids
    return FleetReport([
        run_shard(jobs, num_shards, sid, base_path, transport=transport,
                  **run_kwargs)
        for sid in ids
    ])


# -- reconciliation -------------------------------------------------------

@dataclasses.dataclass
class SyncReport:
    base_path: str
    absorbed: Dict[str, int]          # shard store path -> records absorbed
    skipped: List[str]                # shard stores not found (crashed/late)
    keys: int                         # merged store size
    db: ScheduleDatabase = dataclasses.field(repr=False, default=None)
    corrupt: Dict[str, int] = dataclasses.field(default_factory=dict)
    pulled: List[str] = dataclasses.field(default_factory=list)

    @property
    def corrupt_lines(self) -> int:
        """Total source lines dropped as corrupt during the merge. Non-zero
        means the sync was lossy: records existed that no store absorbed —
        re-run sync after the writers finish, and treat it as a hard
        failure under ``sync --verify``."""
        return sum(self.corrupt.values())


def sync(base_path: str, num_shards: int, provenance: bool = True,
         compact: bool = True, missing_ok: bool = True,
         transport=None, staging_dir: Optional[str] = None) -> SyncReport:
    """Merge every present shard store into the base store. Missing shard
    stores (a crashed or not-yet-finished host) are skipped and reported —
    re-running ``sync`` after the shard resumes completes the merge, and
    re-syncing an already-merged shard is a no-op (the total record order
    makes absorption idempotent).

    With a ``transport`` (spec or instance), shard stores are *pulled*
    from the channel into ``staging_dir`` (default ``<base>.staging/``)
    with manifest/sha1 verification instead of being read off a shared
    filesystem; shards not yet pushed are skipped exactly like missing
    files. Sources are read under their cross-process flock either way,
    and per-source corrupt-line counts are reported (see
    ``SyncReport.corrupt_lines``)."""
    base_path = os.fspath(base_path)
    pulled: List[str] = []
    if transport is not None:
        from repro.tuna.transport import resolve_transport

        from repro.tuna.transport import IntegrityError, TransportError

        t = resolve_transport(transport)
        staging = os.fspath(staging_dir) if staging_dir else \
            base_path + ".staging"
        present, skipped = [], []
        for i in range(num_shards):
            name = shard_object_name(base_path, i)
            if not shard_present(base_path, i, transport=t):
                skipped.append(name)
                continue
            local = os.path.join(staging, name)
            try:
                t.pull(name, local)
            except IntegrityError:
                raise  # genuinely corrupt blob: never merge, never skip
            except TransportError:
                # raced a re-push between exists() and pull() (manifest
                # retracted mid-window): the shard is "not pushed yet"
                skipped.append(name)
                continue
            present.append(local)
            pulled.append(name)
    else:
        present, skipped = [], []
        for i in range(num_shards):
            p = shard_store_path(base_path, i)
            (present if shard_present(base_path, i) else skipped).append(p)
    if skipped and not missing_ok:
        raise FileNotFoundError(f"missing shard stores: {skipped}")
    db, stats, corrupt = ScheduleDatabase.sync(
        base_path, present, provenance=provenance, compact=compact)
    return SyncReport(base_path, stats, skipped, len(db), db,
                      corrupt=corrupt, pulled=pulled)


def divergence(a, b, label_a: str = "a", label_b: str = "b") -> List[str]:
    """Human-readable differences between two stores' best-record sets
    (``ScheduleDatabase`` or ``ScheduleCache``), ignoring merge provenance.
    Empty list == equivalent; used by ``sync --verify`` to fail CI on any
    fleet-vs-single-process divergence."""
    recs_a = {r.key: r for r in a.records()}
    recs_b = {r.key: r for r in b.records()}
    msgs = []

    def _meta(rec: ScheduleRecord) -> Dict:
        # bookkeeping (provenance, tuned_at) never counts as divergence:
        # two hosts tuning the same matrix at different times ARE converged
        return strip_bookkeeping(rec.meta)

    for key in sorted(set(recs_a) | set(recs_b)):
        ra, rb = recs_a.get(key), recs_b.get(key)
        if ra is None:
            msgs.append(f"{key}: only in {label_b}")
        elif rb is None:
            msgs.append(f"{key}: only in {label_a}")
        else:
            for field, va, vb in (
                ("config", ra.config, rb.config),
                ("score", ra.score, rb.score),
                ("evaluations", ra.evaluations, rb.evaluations),
                ("meta", _meta(ra), _meta(rb)),
            ):
                if va != vb:
                    msgs.append(f"{key}: {field} differs "
                                f"({label_a}={va!r}, {label_b}={vb!r})")
    return msgs
