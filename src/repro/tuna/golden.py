"""Golden schedule releases + ahead-of-time compiled kernel bundles.

The MITuna promotion model: a tuned store is a *moving* target — the fleet
appends to it continuously — so nothing downstream should trust "whatever
the store says today". A **golden release** freezes the best-record set for
one ``(target, cost-model version)`` into a content-addressed artifact that
is *blessed* by a regression gate: promotion fails if any (op, target)
schedule scores worse under the cost model than the previous golden (or
vanished from the store), unless the regression is explicitly ``--waive``d
— and every waiver is recorded in the release manifest, so an audit of a
release always answers "who accepted this getting slower, and from what to
what". This mirrors MITuna's ``populate_golden`` versioned find/fast DBs,
with the TPU learned-performance-model lesson baked in: gate a release
against its predecessor *before* anything serves it.

From a golden release, :func:`build_kernel_bundle` ahead-of-time lowers and
compiles every scheduled Pallas kernel (``kernels/matmul.py``,
``kernels/flash_attention.py``) via ``jax.jit(...).lower(...).compile()``
and serializes the executables (``jax.experimental.serialize_executable``)
into a **kernel bundle** — one manifest-verified JSON artifact, shippable
over the existing ``repro.tuna.transport`` channels. A serve process that
loads the bundle (``launch/serve.py --kernel-bundle``, or
``kernels.ops.use_kernel_bundle``) dispatches bundled kernel calls straight
to the deserialized executable: **zero Pallas traces, zero compiles** at
cold start — ``benchmarks/compile_time.py``'s Table II metric driven to a
dictionary probe. The bundle also embeds the full golden schedule set, so
``core.tuner`` gains a bundle-first lookup tier (bundle → snapshot cache →
DB → cost model) and a bundle alone serves block-spec picks with no
snapshot or store attached.

Like the rest of ``repro.tuna``, this module imports no jax at module
scope — promotion and the regression gate run anywhere; only bundle
*building* and executable *loading* touch jax (lazily).
"""
from __future__ import annotations

import base64
import dataclasses
import hashlib
import json
import os
import sys
import tempfile
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import op_registry
from repro.core.cost_model import COST_MODEL_VERSION
from repro.tuna.cache import (
    StaleSnapshotError,
    _payload,
    read_snapshot_header,
)
from repro.tuna.db import Key, ScheduleRecord, record_beats

GOLDEN_SCHEMA = "tuna-golden-v1"
GOLDEN_POINTER_SCHEMA = "tuna-golden-pointer-v1"
BUNDLE_SCHEMA = "tuna-kernel-bundle-v1"
BUNDLE_POINTER_SCHEMA = "tuna-bundle-pointer-v1"

# dtype_bytes in an op signature -> concrete dtype the AOT executable is
# compiled for (the same widths the spaces/tuner use throughout)
_DTYPE_BY_BYTES = op_registry.DTYPE_BY_BYTES


class GoldenError(RuntimeError):
    """A golden release operation failed (bad artifact, no records)."""


class BundleError(RuntimeError):
    """A kernel bundle failed to load or verify (corrupt payload, wrong
    backend/schema) — never serve executables out of it."""


@dataclasses.dataclass(frozen=True)
class Regression:
    """One schedule that got worse (or vanished) vs the previous golden."""

    op: str
    target: str
    version: str
    kind: str                      # "slower" | "lost"
    old_score: float
    new_score: Optional[float] = None   # None when kind == "lost"
    waived_by: Optional[str] = None     # the --waive spec that accepted it

    @property
    def key(self) -> Key:
        return (self.op, self.target, self.version)

    def describe(self) -> str:
        if self.kind == "lost":
            return (f"{self.op} @ {self.target}: present in the previous "
                    f"golden (score {self.old_score:.3e}) but missing from "
                    f"the candidate — lost coverage")
        return (f"{self.op} @ {self.target}: score regressed "
                f"{self.old_score:.3e} -> {self.new_score:.3e} "
                f"({self.new_score / max(self.old_score, 1e-300):.3f}x)")


class GoldenRegressionError(GoldenError):
    """Promotion refused: schedules regressed vs the previous golden and
    were not waived. ``.regressions`` lists every blocking one."""

    def __init__(self, regressions: Sequence[Regression]):
        self.regressions = list(regressions)
        lines = "\n".join(f"  {r.describe()}" for r in self.regressions)
        super().__init__(
            f"{len(self.regressions)} schedule(s) regress vs the previous "
            f"golden release:\n{lines}\n"
            f"Fix the store (or the cost model), or accept explicitly with "
            f"--waive 'OP[@TARGET]' per regression — waivers are recorded "
            f"in the release manifest.")


def find_regressions(new_index: Dict[Key, ScheduleRecord],
                     old_records: Iterable[ScheduleRecord],
                     ) -> List[Regression]:
    """Gate a candidate best-record index against the previous golden's
    records: every key the old release blessed must still exist and must
    not score worse (scores are pure cost-model outputs — deterministic —
    so the comparison is exact, no tolerance band). New keys are always
    welcome; they had no blessed predecessor to regress from."""
    out: List[Regression] = []
    for old in old_records:
        new = new_index.get(old.key)
        if new is None:
            out.append(Regression(op=old.op, target=old.target,
                                  version=old.version, kind="lost",
                                  old_score=old.score))
        elif new.score > old.score:
            out.append(Regression(op=old.op, target=old.target,
                                  version=old.version, kind="slower",
                                  old_score=old.score, new_score=new.score))
    return out


def waiver_matches(spec: str, reg: Regression) -> bool:
    """``--waive`` spec semantics: ``OP`` (exact op signature, every
    target) or ``OP@TARGET`` (one key). No globs — a waiver is a deliberate
    per-schedule exception, not a blanket."""
    if spec == reg.op:
        return True
    return spec == f"{reg.op}@{reg.target}"


@dataclasses.dataclass
class GoldenInfo:
    """What ``GoldenManager.promote`` did."""

    name: str
    path: str
    latest: str
    target: str
    sha1: str
    count: int
    rebuilt: bool
    repointed: bool
    predecessor: Optional[str]          # previous golden release name
    waived: List[Regression] = dataclasses.field(default_factory=list)
    gated_against: int = 0              # predecessor records checked


class GoldenManager:
    """Lifecycle of golden releases in a directory, one lineage per
    ``(target, COST_MODEL_VERSION)``.

    Names are content-addressed like snapshots
    (``golden.<target>.<cm-version>-<digest>.json``) with an atomic
    ``golden.<target>.latest.json`` pointer per target. A cost-model bump
    starts a fresh lineage: the first promotion under a new
    ``COST_MODEL_VERSION`` has no predecessor to regress from (old scores
    are not comparable), exactly like snapshot staleness."""

    def __init__(self, out_dir: str, prefix: str = "golden"):
        self.out_dir = os.fspath(out_dir)
        self.prefix = prefix

    # -- naming -----------------------------------------------------------

    def latest_path(self, target: str) -> str:
        return os.path.join(self.out_dir,
                            f"{self.prefix}.{target}.latest.json")

    def release_name(self, target: str, sha1: str) -> str:
        return f"{self.prefix}.{target}.{COST_MODEL_VERSION}-{sha1[:12]}.json"

    def bundle_name(self, target: str, sha1: str) -> str:
        return f"bundle.{target}.{COST_MODEL_VERSION}-{sha1[:12]}.json"

    def bundle_latest_path(self, target: str) -> str:
        return os.path.join(self.out_dir, f"bundle.{target}.latest.json")

    # -- reads ------------------------------------------------------------

    def current(self, target: str) -> Optional[Dict]:
        """Header of the release the ``latest`` pointer names, or None."""
        try:
            ptr = read_snapshot_header(self.latest_path(target))
        except (FileNotFoundError, ValueError):
            return None
        if ptr.get("schema") != GOLDEN_POINTER_SCHEMA:
            return None
        return ptr

    def load_release(self, path: str,
                     ) -> Tuple[Dict, List[ScheduleRecord]]:
        """Load + verify a golden release file (follows a ``latest``
        pointer): returns ``(header, records)``. Digest verification uses
        the same canonical payload as snapshots — a torn transport copy
        fails loudly here, never at the regression gate."""
        path = os.fspath(path)
        with open(path, "r", encoding="utf-8") as f:
            obj = json.load(f)
        if isinstance(obj, dict) and \
                obj.get("schema") == GOLDEN_POINTER_SCHEMA:
            target = os.path.join(os.path.dirname(os.path.abspath(path)),
                                  obj["release"])
            return self.load_release(target)
        if not isinstance(obj, dict) or obj.get("schema") != GOLDEN_SCHEMA:
            schema = obj.get("schema") if isinstance(obj, dict) else None
            raise GoldenError(f"{path}: not a golden release "
                              f"(schema={schema!r}, want {GOLDEN_SCHEMA!r})")
        digest = hashlib.sha1(_payload(obj["records"]).encode()).hexdigest()
        if digest != obj.get("sha1"):
            raise GoldenError(
                f"{path}: golden release digest mismatch (corrupt or torn "
                f"copy); re-promote with `python -m repro.tuna golden`")
        records = [ScheduleRecord.from_dict(r) for r in obj["records"]]
        return obj, records

    def previous(self, target: str,
                 ) -> Tuple[Optional[Dict], List[ScheduleRecord]]:
        """The predecessor release for this target *and* cost-model
        version — a pointer naming a release from another cost-model
        lineage yields no predecessor (scores are not comparable across
        versions, so there is nothing to gate against)."""
        ptr = self.current(target)
        if ptr is None or ptr.get("cost_model_version") != COST_MODEL_VERSION:
            return None, []
        try:
            return self.load_release(
                os.path.join(self.out_dir, ptr["release"]))
        except FileNotFoundError:
            return None, []

    # -- promotion --------------------------------------------------------

    def promote(self, records: Sequence[ScheduleRecord], target: str,
                waive: Sequence[str] = (), force: bool = False,
                source: str = "") -> GoldenInfo:
        """Freeze the best records for ``(target, COST_MODEL_VERSION)``
        into a golden release, gated against the previous golden.

        ``records`` may span targets/versions — only matching ones
        participate. Raises :class:`GoldenRegressionError` when any
        schedule regresses (slower score, or lost coverage) and no
        ``waive`` spec covers it; waived regressions are recorded in the
        release manifest. Re-promoting identical content is a no-op
        (content-addressed, like ``SnapshotManager.ensure``)."""
        index: Dict[Key, ScheduleRecord] = {}
        for rec in records:
            if rec.target != target or rec.version != COST_MODEL_VERSION:
                continue
            cur = index.get(rec.key)
            if cur is None or record_beats(rec, cur):
                index[rec.key] = rec
        if not index:
            raise GoldenError(
                f"no records for target {target!r} under cost-model "
                f"version {COST_MODEL_VERSION!r} — nothing to promote")

        prev_hdr, prev_records = self.previous(target)
        # the release header carries its content sha1, not its own filename
        # (the name is derived); reconstruct it for the manifest lineage
        prev_name = (self.release_name(target, prev_hdr["sha1"])
                     if prev_hdr else None)
        regressions = find_regressions(index, prev_records)
        waived: List[Regression] = []
        blocking: List[Regression] = []
        for reg in regressions:
            spec = next((w for w in waive if waiver_matches(w, reg)), None)
            if spec is not None:
                waived.append(dataclasses.replace(reg, waived_by=spec))
            else:
                blocking.append(reg)
        if blocking:
            raise GoldenRegressionError(blocking)

        best = [index[k] for k in sorted(index)]
        payload = [dataclasses.asdict(r) for r in best]
        digest = hashlib.sha1(_payload(payload).encode()).hexdigest()
        name = self.release_name(target, digest)
        path = os.path.join(self.out_dir, name)
        rebuilt = force or not os.path.exists(path)
        if rebuilt:
            obj = {
                # header-first like snapshots: identity fields come before
                # the record array so read_snapshot_header stays cheap
                "schema": GOLDEN_SCHEMA,
                "target": target,
                "cost_model_version": COST_MODEL_VERSION,
                "count": len(payload),
                "sha1": digest,
                "built_at": round(time.time(), 3),
                "source": source,
                "predecessor": prev_name,
                "waivers": [dataclasses.asdict(w) for w in waived],
                "records": payload,
            }
            _atomic_write_json(path, obj)
        cur = self.current(target)
        repointed = cur is None or cur.get("release") != name
        if repointed:
            _atomic_write_json(self.latest_path(target), {
                "schema": GOLDEN_POINTER_SCHEMA,
                "release": name,
                "target": target,
                "sha1": digest,
                "count": len(payload),
                "cost_model_version": COST_MODEL_VERSION,
            }, sort_keys=True)
        return GoldenInfo(
            name=name, path=path, latest=self.latest_path(target),
            target=target, sha1=digest, count=len(payload), rebuilt=rebuilt,
            repointed=repointed, predecessor=prev_name,
            waived=waived, gated_against=len(prev_records))

    def publish(self, transport, info: GoldenInfo,
                bundle: Optional["BundleInfo"] = None) -> List:
        """Push a promoted release (payload before pointer, like
        ``SnapshotManager.publish``) and optionally its kernel bundle over
        a transport. Returns the manifests."""
        from repro.tuna.transport import resolve_transport

        t = resolve_transport(transport)
        manifests = [t.push(info.path, info.name)]
        manifests.append(t.push(info.latest,
                                os.path.basename(info.latest)))
        if bundle is not None:
            manifests.append(t.push(bundle.path, bundle.name))
            if bundle.latest:
                manifests.append(t.push(bundle.latest,
                                        os.path.basename(bundle.latest)))
        return manifests


def _atomic_write_json(path: str, obj: Dict, sort_keys: bool = False,
                       ) -> None:
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".golden.tmp")
    try:
        with os.fdopen(fd, "w", encoding="utf-8") as f:
            json.dump(obj, f, default=float, sort_keys=sort_keys)
            f.write("\n")
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


# -- AOT kernel bundles -----------------------------------------------------


@dataclasses.dataclass
class BundlePlan:
    """One record the bundle builder knows how to AOT-compile."""

    record: ScheduleRecord
    kernel: str                     # "matmul" | "flash"
    in_avals: List[Tuple[Tuple[int, ...], str]]   # per-arg (shape, dtype)
    params: Dict                    # semantic knobs baked into the compile


def plan_bundle_entries(records: Iterable[ScheduleRecord],
                        ) -> Tuple[List[BundlePlan], List[Tuple[str, str]]]:
    """Partition golden records into AOT-compilable kernel plans and
    ``(op, why)`` skips, resolving each record's op signature through the
    operator registry (``OpDef.bundle_fn`` reconstructs shapes/dtypes — no
    string parsing here). Families without a Pallas kernel, unparseable
    signatures and knob-mismatched records (e.g. cpu-knob schedules) are
    skipped with a reason; they still ride in the bundle's schedule index,
    they just have no executable. A skip never refuses the whole release."""
    plans: List[BundlePlan] = []
    skipped: List[Tuple[str, str]] = []
    for rec in records:
        try:
            spec = op_registry.bundle_for(rec.op, rec.config)
        except op_registry.BundleSkip as e:
            skipped.append((rec.op, e.reason))
            continue
        plans.append(BundlePlan(
            record=rec, kernel=spec.kernel,
            in_avals=[(tuple(shape), dtype)
                      for shape, dtype in spec.in_avals],
            params=dict(spec.params)))
    return plans, skipped


def _exec_key(kernel: str, in_avals: Sequence[Tuple[Sequence[int], str]],
              params: Optional[Dict] = None) -> str:
    """Canonical runtime-lookup key for an AOT executable: kernel family +
    concrete input (shape, dtype) list + the semantic knobs baked into the
    compile. Built identically by the bundle builder and the dispatch
    site, so equality is string equality."""
    return json.dumps({
        "kernel": kernel,
        "in": [[list(shape), str(dtype)] for shape, dtype in in_avals],
        "params": dict(params or {}),
    }, sort_keys=True, default=float)


def _build_plan_executable(plan: BundlePlan, interpret: bool):
    """Trace + lower + compile one plan via the AOT path; returns the
    serialized executable bytes. jax is imported here, not at module
    scope — promotion never needs it."""
    import functools

    import jax
    import jax.numpy as jnp
    from jax.experimental import serialize_executable

    args = [jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))
            for shape, dtype in plan.in_avals]
    cfg = plan.record.config
    if plan.kernel == "matmul":
        from repro.kernels.matmul import matmul_pallas

        fn = functools.partial(matmul_pallas, bm=cfg["bm"], bn=cfg["bn"],
                               bk=cfg["bk"], interpret=interpret)
    elif plan.kernel == "flash":
        from repro.kernels.flash_attention import flash_attention_pallas

        fn = functools.partial(
            flash_attention_pallas, causal=plan.params["causal"],
            scale=plan.params["scale"], block_q=cfg["block_q"],
            block_k=cfg["block_k"], interpret=interpret)
    else:  # pragma: no cover - plan_bundle_entries only emits the two
        raise BundleError(f"unknown kernel family {plan.kernel!r}")
    compiled = jax.jit(fn).lower(*args).compile()
    payload, _, _ = serialize_executable.serialize(compiled)
    return payload


@dataclasses.dataclass
class BundleInfo:
    name: str
    path: str
    latest: Optional[str]
    target: str
    sha1: str
    entries: int
    schedules: int
    skipped: List[Tuple[str, str]]


def build_kernel_bundle(records: Sequence[ScheduleRecord], out_dir: str,
                        target: str, golden_name: Optional[str] = None,
                        interpret: Optional[bool] = None,
                        prefix: str = "bundle",
                        write_pointer: bool = True) -> BundleInfo:
    """AOT-compile every bundleable golden record into a kernel bundle.

    The artifact is one JSON file: header (schema, digest, backend,
    jax/jaxlib versions — executables are not portable across those), the
    full golden **schedule index** (so the bundle alone is a lookup tier),
    and per-kernel **entries** carrying the serialized executable
    (base64). ``interpret=None`` picks Pallas interpret mode off-TPU —
    the same dispatch rule ``kernels.ops`` uses at runtime."""
    import jax
    import jaxlib

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    plans, skipped = plan_bundle_entries(records)
    if skipped:
        reasons: Dict[str, int] = {}
        for _, why in skipped:
            reasons[why] = reasons.get(why, 0) + 1
        detail = "; ".join(f"{n}x {why}" for why, n in sorted(reasons.items()))
        print(f"[golden] {len(skipped)} of {len(records)} record(s) "
              f"not bundleable, kept schedule-index-only: {detail}",
              file=sys.stderr)
    entries = []
    for plan in plans:
        payload = _build_plan_executable(plan, interpret)
        entries.append({
            "op": plan.record.op,
            "kernel": plan.kernel,
            "target": plan.record.target,
            "version": plan.record.version,
            "config": dict(plan.record.config),
            "score": float(plan.record.score),
            "in_avals": [[list(shape), dtype]
                         for shape, dtype in plan.in_avals],
            "params": dict(plan.params),
            "exec_sha1": hashlib.sha1(payload).hexdigest(),
            "executable_b64": base64.b64encode(payload).decode("ascii"),
        })
    schedules = [dataclasses.asdict(r) for r in records]
    digest = hashlib.sha1(
        _payload(entries + schedules).encode()).hexdigest()
    name = f"{prefix}.{target}.{COST_MODEL_VERSION}-{digest[:12]}.json"
    path = os.path.join(out_dir, name)
    obj = {
        "schema": BUNDLE_SCHEMA,
        "target": target,
        "cost_model_version": COST_MODEL_VERSION,
        "golden": golden_name,
        "backend": jax.default_backend(),
        "interpret": interpret,
        "jax_version": jax.__version__,
        "jaxlib_version": jaxlib.__version__,
        "count": len(entries),
        "schedule_count": len(schedules),
        "sha1": digest,
        "built_at": round(time.time(), 3),
        "skipped_count": len(skipped),
        "skipped": [list(s) for s in skipped],
        "schedules": schedules,
        "entries": entries,
    }
    _atomic_write_json(path, obj)
    latest = None
    if write_pointer:
        latest = os.path.join(out_dir, f"{prefix}.{target}.latest.json")
        _atomic_write_json(latest, {
            "schema": BUNDLE_POINTER_SCHEMA,
            "bundle": name,
            "target": target,
            "sha1": digest,
            "count": len(entries),
            "cost_model_version": COST_MODEL_VERSION,
        }, sort_keys=True)
    return BundleInfo(name=name, path=path, latest=latest, target=target,
                      sha1=digest, entries=len(entries),
                      schedules=len(schedules), skipped=skipped)


class KernelBundle:
    """A loaded kernel bundle: AOT executables + the golden schedule index.

    Two read surfaces, both lock-free after load:

    * :meth:`best` — ``(op, target, version)`` → golden ``ScheduleRecord``;
      what ``core.tuner`` consults as the first lookup tier. Immutable,
      like ``ScheduleCache`` (the tuner's write-back gate respects it).
    * :meth:`executable` — ``(kernel, concrete args, params)`` → a callable
      wrapping the deserialized compiled executable, or ``None``.
      Deserialization is lazy and memoised; a hit performs **zero** Pallas
      traces and zero compiles.
    """

    immutable = True

    def __init__(self, obj: Dict, source: str = "<memory>"):
        self.source = source
        self.target = obj.get("target")
        self.golden = obj.get("golden")
        self.backend = obj.get("backend")
        self.interpret = bool(obj.get("interpret", False))
        self.cost_model_version = obj.get("cost_model_version")
        self.sha1 = obj.get("sha1")
        self.built_at = obj.get("built_at")
        self._entries: Dict[str, Dict] = {}
        self._loaded: Dict[str, object] = {}   # exec key -> callable
        self._best: Dict[Key, ScheduleRecord] = {}
        for rec_obj in obj.get("schedules", []):
            rec = ScheduleRecord.from_dict(rec_obj)
            cur = self._best.get(rec.key)
            if cur is None or record_beats(rec, cur):
                self._best[rec.key] = rec
        for e in obj.get("entries", []):
            self._entries[_exec_key(e["kernel"], [
                (tuple(shape), dtype) for shape, dtype in e["in_avals"]
            ], e.get("params"))] = e
        self.exec_hits = 0
        self.exec_misses = 0
        self.hits = 0      # schedule-tier counters, mirroring ScheduleCache
        self.misses = 0

    # -- load / verify ----------------------------------------------------

    @classmethod
    def load(cls, path: str) -> "KernelBundle":
        """Load + verify a bundle file (follows a ``latest`` pointer).

        Refuses: wrong schema, digest mismatch (torn transport copy), a
        different ``COST_MODEL_VERSION`` (the schedule tier would miss on
        every key — same ``StaleSnapshotError`` discipline as snapshots),
        or a different jax *backend* (serialized executables are compiled
        artifacts; a cpu-built bundle must never pretend to serve tpu)."""
        import jax

        path = os.fspath(path)
        with open(path, "r", encoding="utf-8") as f:
            obj = json.load(f)
        if isinstance(obj, dict) and \
                obj.get("schema") == BUNDLE_POINTER_SCHEMA:
            target = os.path.join(os.path.dirname(os.path.abspath(path)),
                                  obj["bundle"])
            return cls.load(target)
        if not isinstance(obj, dict) or obj.get("schema") != BUNDLE_SCHEMA:
            schema = obj.get("schema") if isinstance(obj, dict) else None
            raise BundleError(f"{path}: not a kernel bundle "
                              f"(schema={schema!r}, want {BUNDLE_SCHEMA!r})")
        digest = hashlib.sha1(_payload(
            obj.get("entries", []) + obj.get("schedules", [])
        ).encode()).hexdigest()
        if digest != obj.get("sha1"):
            raise BundleError(
                f"{path}: bundle digest mismatch (corrupt or torn copy); "
                f"rebuild with `python -m repro.tuna golden --bundle`")
        if obj.get("cost_model_version") != COST_MODEL_VERSION:
            raise StaleSnapshotError(
                f"{path}: kernel bundle was built for cost-model version "
                f"{obj.get('cost_model_version')!r} but this process runs "
                f"{COST_MODEL_VERSION!r}; re-promote and rebuild the "
                f"bundle (`python -m repro.tuna golden --bundle`)")
        backend = jax.default_backend()
        if obj.get("backend") != backend:
            raise BundleError(
                f"{path}: bundle executables were compiled for backend "
                f"{obj.get('backend')!r} but this process runs "
                f"{backend!r}; AOT executables are not portable across "
                f"backends — rebuild the bundle on this platform")
        return cls(obj, source=path)

    # -- schedule tier (core.tuner consults this first) -------------------

    def best(self, op: str, target: str,
             version: str = COST_MODEL_VERSION) -> Optional[ScheduleRecord]:
        rec = self._best.get((op, target, version))
        if rec is None:
            self.misses += 1
        else:
            self.hits += 1
        return rec

    def records(self) -> List[ScheduleRecord]:
        return [self._best[k] for k in sorted(self._best)]

    def add(self, *args, **kwargs):
        raise TypeError(
            "KernelBundle is an immutable release artifact; write to the "
            "ScheduleDatabase and re-promote (`python -m repro.tuna "
            "golden --bundle`)")

    # -- executable tier (kernels.ops dispatches through this) ------------

    def executable(self, kernel: str, args: Sequence,
                   params: Optional[Dict] = None):
        """The AOT executable matching ``kernel`` called on concrete
        ``args`` with semantic ``params``, or ``None`` (caller falls back
        to the ordinary trace-and-compile path)."""
        key = _exec_key(kernel, [(tuple(a.shape), a.dtype.name)
                                 for a in args], params)
        fn = self._loaded.get(key)
        if fn is None:
            entry = self._entries.get(key)
            if entry is None:
                self.exec_misses += 1
                return None
            fn = self._deserialize(key, entry)
        self.exec_hits += 1
        return fn

    def _deserialize(self, key: str, entry: Dict):
        import jax
        from jax.experimental import serialize_executable

        payload = base64.b64decode(entry["executable_b64"])
        if hashlib.sha1(payload).hexdigest() != entry.get("exec_sha1"):
            raise BundleError(
                f"{self.source}: executable payload for {entry['op']!r} "
                f"does not match its digest — corrupt bundle")
        # the kernels take positional array args and return one array, so
        # the calling convention's pytrees are reconstructible without
        # pickling PyTreeDefs into the artifact
        in_tree = jax.tree_util.tree_structure(
            (tuple(0 for _ in entry["in_avals"]), {}))
        out_tree = jax.tree_util.tree_structure(0)
        fn = serialize_executable.deserialize_and_load(
            payload, in_tree, out_tree)
        self._loaded[key] = fn
        return fn

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Key) -> bool:
        return key in self._best

    def describe(self) -> str:
        return (f"{len(self._entries)} AOT kernels / "
                f"{len(self._best)} schedules "
                f"[{self.backend}, {self.cost_model_version}]"
                + (f" from golden {self.golden}" if self.golden else ""))
