"""Offline training of the learned ranker from the JSONL schedule store.

The store's append-only *log* (not the best-record index — that keeps only
winners) is the training set: every line is one (op signature, target,
config, score) sample, which is exactly what TLP and the TPU learned
performance model train on. ``train_from_store`` reads the full log,
reconstructs each record's schedule space from its op signature
(``core.learned.space_from_signature``), featurizes statically, and fits
the ridge ranker with per-lineage target standardisation — datasheet
``cm1`` scores, host-calibrated ``cm1-cal-<fp>`` scores, and measured
``cm1-meas`` seconds never mix scales.

``LearnedManager`` is the artifact lifecycle, mirroring
``SnapshotManager``'s ensure-on-change contract: artifacts get
content-addressed names (``learned.<version>-<digest12>.json``) plus an
atomic ``latest`` pointer that records the sha1 of the *training rows* the
model was fitted from — ``ensure()`` retrains exactly when the store's
training content or the cost-model version changed and is a cheap no-op
otherwise (safe to run every controller reconcile), and ``publish`` ships
payload-before-pointer over any ``repro.tuna.transport`` channel.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_model import COST_MODEL_VERSION
from repro.core.learned import (
    LEARNED_POINTER_SCHEMA,
    LearnedRanker,
    featurize,
    fit_ranker,
    load_ranker,
    save_ranker,
    space_from_signature,
)
from repro.hw import get_target
from repro.tuna.db import ScheduleDatabase, ScheduleRecord


def iter_log_records(db_path: str) -> List[ScheduleRecord]:
    """Every parseable record in the store's log — full history, not just
    the per-key winners the index keeps. Superseded records are the
    valuable part of a training set: they say which configs *lost*."""
    db = ScheduleDatabase(None)
    return list(db._iter_file(os.fspath(db_path), lock=True))


def is_training_row(rec: ScheduleRecord) -> bool:
    """A record the ranker may train on: scored under this cost-model
    family (``cm1...`` lineages, measured ``cm1-meas`` included), and NOT
    written by a learned ranker itself (``+lr`` in the version) — a model
    must never train on its own hybrid write-backs."""
    return (rec.version.startswith(COST_MODEL_VERSION)
            and "+lr" not in rec.version)


def training_rows(records: Sequence[ScheduleRecord]) -> List[ScheduleRecord]:
    return [r for r in records if is_training_row(r)]


def training_sha1(rows: Sequence[ScheduleRecord]) -> str:
    """Content digest of the training set (order-independent, bookkeeping
    meta excluded) — what the ``latest`` pointer records and ``ensure``
    compares, so a fleet sync that only reorders or restamps lines does
    not trigger a retrain."""
    canon = sorted(
        json.dumps([r.op, r.target, r.version, r.config, float(r.score)],
                   sort_keys=True, default=float)
        for r in rows
    )
    return hashlib.sha1("\n".join(canon).encode()).hexdigest()


def build_dataset(
    rows: Sequence[ScheduleRecord], augment: int = 0, seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray, List[str], int]:
    """Featurize training rows → ``(X, y, group_ids, skipped)``.

    Group ids are ``<version>@<op>@<target>`` — standardisation groups.
    Within a group every score came from the same lineage *and* the same
    schedule space, so relative order is exactly the ranking signal we
    want; across groups nothing is compared. Rows whose space cannot be
    reconstructed (foreign op families) or whose config no longer
    instantiates are skipped, not fatal.

    ``augment > 0`` adds up to that many statically-scored (free, no
    hardware) configs per distinct (op, target) — ``cm1`` lineage — so
    spaces with only a handful of stored winners still teach the model the
    shape of their cost surface.
    """
    X: List[np.ndarray] = []
    y: List[float] = []
    groups: List[str] = []
    skipped = 0
    spaces: Dict[Tuple[str, str], object] = {}

    def space_for(op: str, target_name: str):
        key = (op, target_name)
        if key not in spaces:
            try:
                target = get_target(target_name)
            except (KeyError, ValueError):
                spaces[key] = (None, None)
            else:
                spaces[key] = (space_from_signature(op, target), target)
        return spaces[key]

    for rec in rows:
        space, target = space_for(rec.op, rec.target)
        if space is None or rec.score <= 0:
            skipped += 1
            continue
        try:
            X.append(featurize(space, target, dict(rec.config),
                               hlo_text=rec.meta.get("hlo")))
        except (KeyError, ValueError, TypeError):
            skipped += 1
            continue
        y.append(float(rec.score))
        groups.append(f"{rec.version}@{rec.op}@{rec.target}")

    if augment > 0:
        from repro.core import cost_model

        rng = np.random.default_rng(seed)
        seen = {(r.op, r.target) for r in rows}
        for op, target_name in sorted(seen):
            space, target = space_for(op, target_name)
            if space is None:
                continue
            cfgs = list(space.enumerate(space.size()))
            if len(cfgs) > augment:
                idx = rng.choice(len(cfgs), size=augment, replace=False)
                cfgs = [cfgs[i] for i in sorted(idx)]
            for cfg in cfgs:
                try:
                    prog, meta = space.instantiate(cfg)
                    s = cost_model.evaluate(prog, target, meta)
                    X.append(featurize(space, target, cfg))
                except (KeyError, ValueError, TypeError):
                    continue
                y.append(float(s))
                groups.append(f"{COST_MODEL_VERSION}@{op}@{target_name}")

    if not X:
        return (np.zeros((0, 0)), np.zeros(0), [], skipped)
    return (np.stack(X), np.asarray(y, dtype=np.float64), groups, skipped)


def train_from_store(
    db_path: str, augment: int = 0, seed: int = 0, l2: float = 1e-2,
) -> Tuple[LearnedRanker, str, int, int]:
    """Fit a ranker from a store's log. Returns ``(model, train_sha1,
    n_samples, n_skipped)``. Raises ``ValueError`` when the store yields
    no usable training rows."""
    rows = training_rows(iter_log_records(db_path))
    tsha = training_sha1(rows)
    X, y, groups, skipped = build_dataset(rows, augment=augment, seed=seed)
    if len(y) < 2:
        raise ValueError(
            f"{db_path}: only {len(y)} usable training sample(s) "
            f"({skipped} skipped) — tune more operators into the store "
            f"first (`python -m repro.tuna tune`), or collect measured "
            f"samples (`python -m benchmarks.topk_ratio --collect`)")
    model = fit_ranker(X, y, groups, l2=l2)
    # the artifact records lineage composition at version granularity —
    # the (op, target) refinement used for standardisation stays internal
    by_version: Dict[str, int] = {}
    for g in groups:
        v = g.split("@", 1)[0]
        by_version[v] = by_version.get(v, 0) + 1
    model.lineages = by_version
    return model, tsha, len(y), skipped


# -- artifact lifecycle ------------------------------------------------------

@dataclasses.dataclass
class LearnedInfo:
    """What ``LearnedManager.ensure`` did: the versioned artifact path, the
    ``latest`` pointer path, and whether a retrain happened."""

    name: str
    path: str
    latest: str
    sha1: str
    version: str
    train_sha1: str
    samples: int
    skipped: int
    retrained: bool   # a new versioned artifact was fitted + written
    repointed: bool   # the latest pointer moved
    built_at: Optional[float] = None


class LearnedManager:
    """Keeps a directory of versioned learned-ranker artifacts consistent
    with a store — ``SnapshotManager``'s ensure-on-change contract applied
    to model training. Identity is the pair (training-row sha1, cost-model
    version): a fleet sync that adds records retrains, a restamp/reorder
    does not, and a ``COST_MODEL_VERSION`` bump always does."""

    def __init__(self, db_path: str, out_dir: str, prefix: str = "learned",
                 augment: int = 0, seed: int = 0, l2: float = 1e-2):
        self.db_path = os.fspath(db_path)
        self.out_dir = os.fspath(out_dir)
        self.prefix = prefix
        self.augment = augment
        self.seed = seed
        self.l2 = l2

    @property
    def latest_path(self) -> str:
        return os.path.join(self.out_dir, f"{self.prefix}.latest.json")

    def artifact_name(self, version: str, sha1: str) -> str:
        return f"{self.prefix}.{version}-{sha1[:12]}.json"

    def current(self) -> Optional[Dict]:
        """The latest pointer object, or None when never trained."""
        try:
            with open(self.latest_path, "r", encoding="utf-8") as f:
                obj = json.load(f)
        except (OSError, ValueError):
            return None
        if not isinstance(obj, dict) or \
                obj.get("schema") != LEARNED_POINTER_SCHEMA:
            return None
        return obj

    def load(self) -> LearnedRanker:
        """Load the currently-pointed artifact (verified — see
        ``core.learned.load_ranker``)."""
        return load_ranker(self.latest_path)

    def ensure(self, force: bool = False) -> LearnedInfo:
        """Retrain iff the store's training content or the cost-model
        version changed since the pointed artifact was fitted (or
        ``force``); repoint ``latest`` at the result. Old versioned
        artifacts stay in place for in-flight pulls."""
        rows = training_rows(iter_log_records(self.db_path))
        tsha = training_sha1(rows)
        cur = self.current()
        fresh = (
            not force
            and cur is not None
            and cur.get("train_sha1") == tsha
            and cur.get("cost_model_version") == COST_MODEL_VERSION
            and cur.get("augment") == self.augment
            and cur.get("seed") == self.seed
            and cur.get("l2") == self.l2
            and os.path.exists(os.path.join(self.out_dir, cur["artifact"]))
        )
        if fresh:
            return LearnedInfo(
                name=cur["artifact"],
                path=os.path.join(self.out_dir, cur["artifact"]),
                latest=self.latest_path, sha1=cur.get("sha1", ""),
                version=cur.get("version", ""), train_sha1=tsha,
                samples=int(cur.get("samples", 0)),
                skipped=int(cur.get("skipped", 0)),
                retrained=False, repointed=False,
                built_at=cur.get("built_at"))
        model, tsha, samples, skipped = train_from_store(
            self.db_path, augment=self.augment, seed=self.seed, l2=self.l2)
        name = self.artifact_name(model.version, model.fingerprint())
        path = os.path.join(self.out_dir, name)
        sha1 = save_ranker(model, path)
        repointed = cur is None or cur.get("artifact") != name or \
            cur.get("train_sha1") != tsha
        self._write_pointer(name, sha1, model, tsha, samples, skipped)
        return LearnedInfo(name=name, path=path, latest=self.latest_path,
                           sha1=sha1, version=model.version,
                           train_sha1=tsha, samples=samples, skipped=skipped,
                           retrained=True, repointed=repointed,
                           built_at=model.built_at)

    def _write_pointer(self, name: str, sha1: str, model: LearnedRanker,
                       train_sha1: str, samples: int, skipped: int) -> None:
        obj = {
            "schema": LEARNED_POINTER_SCHEMA,
            "artifact": name,
            "sha1": sha1,
            "fingerprint": model.fingerprint(),
            "version": model.version,
            "cost_model_version": model.cost_model_version,
            "train_sha1": train_sha1,
            "samples": samples,
            "skipped": skipped,
            "lineages": model.lineages,
            "augment": self.augment,
            "seed": self.seed,
            "l2": self.l2,
            "built_at": model.built_at,
        }
        os.makedirs(self.out_dir, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.out_dir, suffix=".pointer.tmp")
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(obj, f, sort_keys=True)
                f.write("\n")
            os.replace(tmp, self.latest_path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def publish(self, transport, info: Optional[LearnedInfo] = None) -> List:
        """``ensure`` + push the versioned artifact then the ``latest``
        pointer over a transport (payload-before-pointer: a puller that
        sees the new pointer can always pull the artifact it names).
        Returns the manifests."""
        from repro.tuna.transport import resolve_transport

        t = resolve_transport(transport)
        if info is None:
            info = self.ensure()
        manifests = [t.push(info.path, info.name)]
        manifests.append(t.push(self.latest_path,
                                os.path.basename(self.latest_path)))
        return manifests
