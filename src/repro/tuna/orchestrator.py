"""Parallel tuning orchestrator — fan (operator space × target) jobs out
across a process pool and stream results into the schedule database.

Static analysis is embarrassingly parallel: scoring needs no device, only
host cores (the paper's §V compilation-time edge), so any machine can be a
tuning worker — the MITuna builder/evaluator split collapses to a process
pool here. Failures retry with capped attempts; every completed job appends
one ``cm1`` record to the store as it lands (no end-of-run barrier).

The worker path imports only numpy-backed modules (no jax), so workers are
cheap to spawn; ``start_method="spawn"`` is the default to stay safe under
hosts where the parent has already initialised threaded runtimes.
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import multiprocessing
import time
import traceback
from typing import Dict, List, Optional, Sequence

from repro.configs.tuna_ops import OPERATORS
from repro.core import tuner
from repro.hw import get_target
from repro.tuna.db import ScheduleDatabase, ScheduleRecord, stamp_tuned_at


@dataclasses.dataclass(frozen=True)
class TuneJob:
    """One unit of work: tune operator ``op`` (a ``configs.tuna_ops`` name)
    for ``target`` with the given search strategy."""

    op: str
    target: str = "tpu_v5e"
    strategy: str = "exhaustive"  # "exhaustive" | "es"
    limit: int = 1024             # exhaustive enumeration cap
    iterations: int = 12          # es knobs
    population: int = 16
    seed: int = 0


@dataclasses.dataclass
class JobFailure:
    job: TuneJob
    error: str
    attempts: int


@dataclasses.dataclass
class RunReport:
    records: List[ScheduleRecord]
    failures: List[JobFailure]
    wall_seconds: float

    @property
    def ok(self) -> bool:
        return not self.failures


def build_space(job: TuneJob):
    try:
        factory = OPERATORS[job.op]
    except KeyError:
        raise KeyError(
            f"unknown operator {job.op!r}; have {sorted(OPERATORS)}")
    return factory(get_target(job.target).kind)


def run_job(job: TuneJob) -> ScheduleRecord:
    """Execute one job to a finished ``cm1`` record (module-level so it
    pickles under spawn)."""
    space = build_space(job)
    target = get_target(job.target)
    default_score = tuner._score_config(space, target,
                                        space.default_config())
    if job.strategy == "exhaustive":
        ranked = tuner.rank_space(space, target, limit=job.limit, db=False)
        cfg, score = ranked[0]
        evaluations = len(ranked)
    elif job.strategy == "es":
        res = tuner.tune(space, target, iterations=job.iterations,
                         population=job.population, seed=job.seed,
                         workers=1, db=False)
        cfg, score, evaluations = res.config, res.score, res.evaluations
    else:
        raise ValueError(f"unknown strategy {job.strategy!r}")
    return ScheduleRecord(
        op=space.signature(),
        target=target.name,
        config=dict(cfg),
        score=score,
        evaluations=evaluations,
        meta=stamp_tuned_at(
            {"strategy": job.strategy, "default_score": default_score}),
    )


def run(
    jobs: Sequence[TuneJob],
    db: Optional[ScheduleDatabase] = None,
    workers: int = 4,
    retries: int = 2,
    start_method: str = "spawn",
    verbose: bool = False,
    runner=run_job,
) -> RunReport:
    """Fan ``jobs`` out over ``workers`` processes (inline when ``workers <=
    1``), retrying each failed job up to ``retries`` extra times, streaming
    completed records into ``db``. ``runner`` must be a picklable
    module-level callable (the fleet and tests substitute it).

    Retry accounting is per *submission*, not per job value: ``TuneJob`` is
    a frozen dataclass, so duplicate jobs in one run compare equal — keying
    attempts by the job itself would make duplicates share one counter and
    exhaust each other's retries."""
    t0 = time.perf_counter()
    records: List[ScheduleRecord] = []
    failures: List[JobFailure] = []

    def _land(rec: ScheduleRecord) -> None:
        if db is not None:
            db.add(rec)
        records.append(rec)
        if verbose:
            print(f"[tuna] {rec.op} @ {rec.target}: score={rec.score:.3e} "
                  f"evals={rec.evaluations} ({rec.meta.get('strategy')})")

    if workers <= 1:
        for job in jobs:
            err, attempts = "", 0
            for attempt in range(retries + 1):
                attempts = attempt + 1
                try:
                    _land(runner(job))
                    break
                except Exception:  # noqa: BLE001
                    err = traceback.format_exc(limit=3)
            else:
                failures.append(JobFailure(job, err, attempts))
        return RunReport(records, failures, time.perf_counter() - t0)

    ctx = multiprocessing.get_context(start_method)
    attempts: Dict[int, int] = {}  # submission index -> attempts so far
    with cf.ProcessPoolExecutor(max_workers=workers, mp_context=ctx) as pool:
        pending = {}
        for idx, job in enumerate(jobs):
            pending[pool.submit(runner, job)] = (idx, job)
            attempts[idx] = 1
        while pending:
            done, _ = cf.wait(pending, return_when=cf.FIRST_COMPLETED)
            for fut in done:
                idx, job = pending.pop(fut)
                try:
                    _land(fut.result())
                except Exception:  # noqa: BLE001
                    if attempts[idx] <= retries:
                        attempts[idx] += 1
                        pending[pool.submit(runner, job)] = (idx, job)
                    else:
                        failures.append(JobFailure(
                            job, traceback.format_exc(limit=3),
                            attempts[idx]))
    return RunReport(records, failures, time.perf_counter() - t0)


def jobs_for(ops: Sequence[str], targets: Sequence[str],
             strategy: str = "exhaustive", limit: int = 1024,
             seed: int = 0) -> List[TuneJob]:
    return [TuneJob(op=op, target=t, strategy=strategy, limit=limit,
                    seed=seed)
            for op in ops for t in targets]
