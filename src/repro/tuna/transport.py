"""Fleet transport — move shard stores and snapshots between hosts.

PR 4's fleet assumed every shard store lands on a shared filesystem before
``sync`` runs. Real fleets ship artifacts instead (AutoTVM tuning logs,
the TPU learned-cost-model's offline/online split): a shard host *pushes*
its store into a channel, the sync host *pulls* whatever shards have
arrived, and the serving side pulls published snapshots. ``Transport`` is
that channel, deliberately tiny — named blobs plus a **manifest** per blob
(sha1 over the payload, record count, cost-model version of the pushing
process) so every pull is integrity-verified with the same digest
discipline the snapshot format already uses: a torn or truncated copy
fails loudly at pull time, never at serve time.

Two implementations ship:

* ``LocalDirTransport`` — a directory as the bucket (shared fs, NFS mount,
  the target of an out-of-band rsync). The baseline, and what CI's
  transport-smoke job drives.
* ``MemoryTransport`` — an in-process object store (class-level buckets
  shared across instances), standing in for an HTTP/object-store channel
  in tests: shard "hosts" and the sync "host" share nothing but the
  bucket name.

``resolve_transport`` turns CLI/env specs into instances::

    dir:///var/tuna/bucket   (or a bare path)  -> LocalDirTransport
    mem://ci-bucket                            -> MemoryTransport
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import threading
from typing import Dict, List, Optional, Union

from repro.core.cost_model import COST_MODEL_VERSION
from repro.tuna.db import _flock

MANIFEST_SCHEMA = "tuna-manifest-v1"
MANIFEST_SUFFIX = ".manifest"


class TransportError(RuntimeError):
    """A transport operation failed (missing object, missing manifest)."""


class IntegrityError(TransportError):
    """Pulled payload does not match its manifest digest (torn/corrupt
    copy) — re-push from the source host instead of serving it."""


@dataclasses.dataclass(frozen=True)
class Manifest:
    """Sidecar metadata pushed with every blob; the pull side verifies
    ``sha1`` before the payload ever reaches a store or a snapshot load."""

    name: str
    sha1: str
    size: int
    records: int                # JSONL lines / snapshot record count
    cost_model_version: str
    schema: str = MANIFEST_SCHEMA

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, blob: Union[str, bytes]) -> "Manifest":
        obj = json.loads(blob)
        if obj.get("schema") != MANIFEST_SCHEMA:
            raise TransportError(
                f"bad manifest (schema={obj.get('schema')!r}, "
                f"want {MANIFEST_SCHEMA!r})")
        return cls(name=str(obj["name"]), sha1=str(obj["sha1"]),
                   size=int(obj["size"]), records=int(obj["records"]),
                   cost_model_version=str(obj["cost_model_version"]))


def _count_records(name: str, data: bytes) -> int:
    """Best-effort record count for the manifest: JSONL stores count
    non-empty lines; snapshot/pointer JSON reads the header ``count``."""
    if name.endswith(".jsonl"):
        return sum(1 for ln in data.splitlines() if ln.strip())
    try:
        from repro.tuna.cache import read_snapshot_header

        return int(read_snapshot_header(data=data.decode()).get("count", 0))
    except (ValueError, UnicodeDecodeError):
        return 0


class Transport:
    """Named-blob channel with manifest-verified pulls.

    Subclasses implement the three raw primitives (``_put``/``_get``/
    ``_names``); push/pull/exists/list and the integrity discipline live
    here so every implementation gets them identically.
    """

    # -- raw primitives (subclass responsibility) ------------------------

    def _put(self, name: str, data: bytes) -> None:
        raise NotImplementedError

    def _get(self, name: str) -> bytes:
        """Raise ``KeyError`` when the blob is absent."""
        raise NotImplementedError

    def _delete(self, name: str) -> None:
        """Remove a blob; absent is a no-op."""
        raise NotImplementedError

    def _names(self) -> List[str]:
        raise NotImplementedError

    # -- the public protocol ---------------------------------------------

    def push(self, local_path: str, name: Optional[str] = None) -> Manifest:
        """Upload ``local_path`` (read under the store flock, so an
        in-flight local writer can't hand us a torn tail) plus its
        manifest. Returns the manifest.

        Write order keeps the manifest a truthful commit marker even on a
        *re*-push (a crashed shard host re-running): retract the old
        manifest, replace the payload, commit the new manifest. A reader
        in the window sees "not pushed yet" and skips — it can never pair
        a fresh payload with a stale manifest."""
        local_path = os.fspath(local_path)
        name = name or os.path.basename(local_path)
        with open(local_path, "rb") as f:
            _flock(f)
            data = f.read()
        man = Manifest(
            name=name,
            sha1=hashlib.sha1(data).hexdigest(),
            size=len(data),
            records=_count_records(name, data),
            cost_model_version=COST_MODEL_VERSION,
        )
        self._delete(name + MANIFEST_SUFFIX)
        self._put(name, data)
        self._put(name + MANIFEST_SUFFIX, man.to_json().encode())
        return man

    def pull(self, name: str, local_path: str) -> Manifest:
        """Download ``name`` to ``local_path`` (atomic temp-file +
        replace), verifying the payload digest against the manifest."""
        try:
            data = self._get(name)
        except KeyError:
            raise TransportError(f"{self.describe()}: no object {name!r}")
        try:
            man = Manifest.from_json(self._get(name + MANIFEST_SUFFIX))
        except KeyError:
            raise TransportError(
                f"{self.describe()}: object {name!r} has no manifest — "
                f"pushed by something other than this transport?")
        digest = hashlib.sha1(data).hexdigest()
        if digest != man.sha1 or len(data) != man.size:
            raise IntegrityError(
                f"{self.describe()}: {name!r} payload does not match its "
                f"manifest (got sha1 {digest[:12]}/{len(data)}B, manifest "
                f"says {man.sha1[:12]}/{man.size}B) — torn or corrupt "
                f"copy; re-push from the source host")
        local_path = os.fspath(local_path)
        d = os.path.dirname(local_path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".pull.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, local_path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return man

    def manifest(self, name: str) -> Manifest:
        try:
            return Manifest.from_json(self._get(name + MANIFEST_SUFFIX))
        except KeyError:
            raise TransportError(f"{self.describe()}: no manifest for "
                                 f"{name!r}")

    def exists(self, name: str) -> bool:
        """True only when the blob *and* its manifest are present. Push
        writes the payload first and the manifest last, so the manifest is
        the commit marker: a sync racing a mid-push shard sees it as
        not-yet-pushed (skipped) instead of pulling a manifest-less blob."""
        names = set(self._names())
        return name in names and name + MANIFEST_SUFFIX in names

    def list(self, prefix: str = "") -> List[str]:
        """Blob names (manifests hidden) under ``prefix``, sorted."""
        return sorted(n for n in self._names()
                      if n.startswith(prefix)
                      and not n.endswith(MANIFEST_SUFFIX))

    def list_shards(self, base_name: str) -> List[str]:
        """Shard-store objects for a base store name: ``fleet.jsonl`` →
        every ``fleet.shardNN.jsonl`` present in the channel."""
        root, ext = os.path.splitext(base_name)
        prefix = f"{root}.shard"
        return [n for n in self.list(prefix)
                if n.endswith(ext or ".jsonl")]

    def describe(self) -> str:
        return type(self).__name__


class LocalDirTransport(Transport):
    """A directory as the bucket — the shared-filesystem / rsync-target
    baseline. Writes are atomic (temp file + ``os.replace``), so a
    concurrent pull never sees a half-pushed blob."""

    def __init__(self, root: str):
        self.root = os.fspath(root)

    def _path(self, name: str) -> str:
        path = os.path.normpath(os.path.join(self.root, name))
        if os.path.commonpath([os.path.abspath(self.root),
                               os.path.abspath(path)]) != \
                os.path.abspath(self.root):
            raise TransportError(f"object name escapes the bucket: {name!r}")
        return path

    def _put(self, name: str, data: bytes) -> None:
        path = self._path(name)
        d = os.path.dirname(path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".push.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def _get(self, name: str) -> bytes:
        try:
            with open(self._path(name), "rb") as f:
                return f.read()
        except FileNotFoundError:
            raise KeyError(name)

    def _delete(self, name: str) -> None:
        try:
            os.unlink(self._path(name))
        except FileNotFoundError:
            pass

    def exists(self, name: str) -> bool:
        # two stats instead of the base class's full bucket walk — sync
        # probes every shard name, so this is O(1) per shard, not O(bucket)
        return (os.path.exists(self._path(name)) and
                os.path.exists(self._path(name + MANIFEST_SUFFIX)))

    def _names(self) -> List[str]:
        out = []
        for dirpath, _, files in os.walk(self.root):
            rel = os.path.relpath(dirpath, self.root)
            for fn in files:
                if fn.endswith((".push.tmp", ".pull.tmp")):
                    continue
                out.append(fn if rel == "." else os.path.join(rel, fn))
        return out

    def describe(self) -> str:
        return f"dir://{self.root}"


class MemoryTransport(Transport):
    """In-process object store: buckets are class-level and shared by
    every instance with the same bucket name, so test "hosts" (or threads)
    reach the same channel without any shared directory — the stand-in for
    an HTTP/object-store transport."""

    _BUCKETS: Dict[str, Dict[str, bytes]] = {}
    _LOCK = threading.Lock()

    def __init__(self, bucket: str = "default"):
        self.bucket = bucket
        with self._LOCK:
            self._blobs = self._BUCKETS.setdefault(bucket, {})

    @classmethod
    def wipe(cls, bucket: Optional[str] = None) -> None:
        """Drop one bucket (or all) — test isolation."""
        with cls._LOCK:
            if bucket is None:
                cls._BUCKETS.clear()
            else:
                cls._BUCKETS.pop(bucket, None)

    def _put(self, name: str, data: bytes) -> None:
        with self._LOCK:
            self._blobs[name] = bytes(data)

    def _get(self, name: str) -> bytes:
        with self._LOCK:
            return self._blobs[name]  # KeyError when absent, per protocol

    def _delete(self, name: str) -> None:
        with self._LOCK:
            self._blobs.pop(name, None)

    def _names(self) -> List[str]:
        with self._LOCK:
            return list(self._blobs)

    def describe(self) -> str:
        return f"mem://{self.bucket}"


def resolve_transport(spec: Union[str, Transport]) -> Transport:
    """CLI/env spec → transport: ``mem://bucket`` → ``MemoryTransport``,
    ``dir://path`` or a bare path → ``LocalDirTransport``; an instance
    passes through."""
    if isinstance(spec, Transport):
        return spec
    spec = os.fspath(spec)
    if spec.startswith("mem://"):
        return MemoryTransport(spec[len("mem://"):] or "default")
    if spec.startswith("dir://"):
        spec = spec[len("dir://"):]
    if not spec:
        raise ValueError("empty transport spec")
    return LocalDirTransport(spec)
