import os

# Tests run single-device (the dry-run pins 512 host devices itself, in a
# subprocess). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
