import os

# Tests run single-device (the dry-run pins 512 host devices itself, in a
# subprocess). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True)
def _no_default_schedule_db():
    """Isolate every test from the process-default schedule DB and serving
    snapshot — without this, a developer's $REPRO_TUNA_DB/$REPRO_TUNA_CACHE
    would warm-hit search-behavior tests and (for the DB) get dirtied by
    their write-backs."""
    from repro.core import tuner

    tuner.set_default_db(None)
    tuner.set_default_cache(None)
    tuner.set_default_bundle(None)
    tuner.set_default_learned(None)
    yield
    tuner.set_default_db(None)
    tuner.set_default_cache(None)
    tuner.set_default_bundle(None)
    tuner.set_default_learned(None)
