"""Fleet controller daemon: lease-tracked dispatch, crash healing, the
reconcile + snapshot loop, the HTTP schedule/health/metrics API, and the
tuned_at / built_at freshness stamps.

The acceptance spine: a controller round on a ``mem://`` transport with
one worker killed mid-shard must observe the failure, re-dispatch the
shard, and converge to a store record-for-record identical to a clean
single-process ``run_fleet`` — zero manual steps.

Like test_fleet.py this module must stay jax-free: everything here is
numpy-backed and in-process (ThreadWorker mode).
"""
import json
import os
import threading
import time
import urllib.request

import pytest

from repro.core.cost_model import COST_MODEL_VERSION
from repro.tuna import cli, fleet, orchestrator
from repro.tuna.cache import ScheduleCache, read_snapshot_header
from repro.tuna.controller import (
    ControllerConfig,
    ControllerMetrics,
    FleetController,
    ThreadWorker,
    start_http,
)
from repro.tuna.db import (
    ScheduleDatabase,
    ScheduleRecord,
    record_to_dict,
    strip_bookkeeping,
)
from repro.tuna.fleet import ShardLease
from repro.tuna.transport import MemoryTransport

JOB_OPS = ["dense_256", "batch_matmul"]
JOB_TARGETS = ["tpu_v5e"]


def _matrix():
    return orchestrator.jobs_for(JOB_OPS, JOB_TARGETS, limit=64)


def _mem(tmp_path) -> MemoryTransport:
    bucket = f"ctl-{os.path.basename(tmp_path)}"
    MemoryTransport.wipe(bucket)
    return MemoryTransport(bucket)


def _cfg(tmp_path, **kw) -> ControllerConfig:
    kw.setdefault("db", str(tmp_path / "ctl" / "fleet.jsonl"))
    kw.setdefault("ops", JOB_OPS)
    kw.setdefault("targets", JOB_TARGETS)
    kw.setdefault("limit", 64)
    kw.setdefault("num_shards", 2)
    kw.setdefault("poll_s", 0.01)
    kw.setdefault("worker_procs", 1)
    kw.setdefault("quiet", True)
    return ControllerConfig(**kw)


def _strip(db):
    """Comparable record tuples with bookkeeping meta (provenance,
    tuned_at) removed — the single-vs-fleet parity form."""
    return [
        (r.op, r.target, r.version, json.dumps(r.config, sort_keys=True),
         r.score, r.evaluations, strip_bookkeeping(r.meta))
        for r in db.records()
    ]


def _rec(op="matmul[x]", score=1.0, meta=None) -> ScheduleRecord:
    return ScheduleRecord(
        op=op, target="tpu_v5e", version=COST_MODEL_VERSION,
        config={"tile": 8}, score=score, evaluations=1,
        meta=dict(meta or {}))


# -- crash-skip probe + lease primitives -----------------------------------

class TestShardPresence:
    def test_shared_fs(self, tmp_path):
        base = str(tmp_path / "f.jsonl")
        assert not fleet.shard_present(base, 0)
        assert fleet.missing_shards(base, 2) == [0, 1]
        fleet.touch_store(fleet.shard_store_path(base, 1))
        assert fleet.shard_present(base, 1)
        assert fleet.missing_shards(base, 2) == [0]

    def test_transport_manifest_is_the_marker(self, tmp_path):
        t = _mem(tmp_path)
        base = str(tmp_path / "f.jsonl")
        assert fleet.missing_shards(base, 2, transport=t) == [0, 1]
        jobs = _matrix()
        run = fleet.run_shard(jobs, 2, 0, base, transport=t, workers=1)
        assert run.ok and run.pushed is not None
        assert fleet.shard_present(base, 0, transport=t)
        # the local file also exists, but with a transport configured the
        # channel is authoritative — shard 1 never pushed
        assert not fleet.shard_present(base, 1, transport=t)


class TestShardLease:
    def test_deadline_and_expiry(self):
        lease = ShardLease(shard_id=0, jobs=3, granted_at=100.0, lease_s=5.0)
        assert lease.deadline == 105.0
        assert lease.last_heartbeat == 100.0
        assert not lease.expired(now=104.9)
        assert lease.expired(now=105.1)
        lease.heartbeat(now=103.0)
        assert lease.last_heartbeat == 103.0
        # heartbeats renew liveness, never the deadline
        assert lease.expired(now=105.1)


class TestThreadWorker:
    def test_exit_codes(self):
        ok = ThreadWorker(lambda cancelled: True)
        bad = ThreadWorker(lambda cancelled: False)
        def _boom(cancelled):
            raise RuntimeError("x")
        crash = ThreadWorker(_boom)
        deadline = time.monotonic() + 10
        while any(w.poll() is None for w in (ok, bad, crash)):
            assert time.monotonic() < deadline
            time.sleep(0.01)
        assert (ok.poll(), bad.poll(), crash.poll()) == (0, 2, 1)

    def test_kill_reports_minus_9_and_cancels(self):
        started = threading.Event()
        def _hang(cancelled):
            started.set()
            cancelled.wait(30)
        w = ThreadWorker(_hang)
        assert started.wait(10)
        assert w.poll() is None
        w.kill()
        assert w.poll() == -9
        assert w.cancelled.is_set()


# -- the acceptance spine: heal a killed worker, converge, match single ----

class TestControllerHealing:
    def test_injected_crash_heals_and_matches_single_run(self, tmp_path):
        """Satellite acceptance: controller on mem://, one worker dies
        mid-shard, the shard is re-dispatched, and the final store is
        record-for-record identical to a clean single-process run_fleet."""
        t = _mem(tmp_path)
        cfg = _cfg(tmp_path, transport=t, inject_crash_shard=0)
        ctl = FleetController(cfg)
        shard0_jobs = ctl._shard_jobs[0]
        rc = ctl.run(exit_when_converged=True)
        assert rc == 0 and ctl.converged and not ctl.wedged

        # the crash was observed and healed exactly once
        assert ctl.metrics.get("shards_healed_total") == 1
        assert ctl.metrics.get("jobs_healed_total") == shard0_jobs
        assert ctl.metrics.get("jobs_failed_total") == shard0_jobs
        assert ctl.attempts[0] == 2 and ctl.attempts[1] == 1
        kinds = [e["event"] for e in ctl.events if e["shard"] == 0]
        assert kinds == ["dispatched", "failed", "healed", "dispatched",
                        "done"]

        # every job completed despite the crash
        total = len(ctl.jobs)
        assert ctl.metrics.get("jobs_done_total") == total
        assert ctl.metrics.get("jobs_dispatched_total") == \
            total + shard0_jobs
        assert ctl.metrics.get("sync_divergence") == 0

        # record-for-record parity with the clean single-process fleet
        clean_base = str(tmp_path / "clean" / "fleet.jsonl")
        assert fleet.run_fleet(ctl.jobs, cfg.num_shards, clean_base,
                               workers=1).ok
        clean = fleet.sync(clean_base, cfg.num_shards)
        merged = ScheduleDatabase(cfg.db)
        assert len(merged) == len(ctl.jobs)
        assert _strip(merged) == _strip(clean.db)

        # the snapshot the controller serves is that store, verbatim
        cache = ScheduleCache.load(ctl.manager.latest_path)
        assert cache.records() == merged.records()

    def test_expired_lease_kills_and_heals(self, tmp_path):
        """A wedged worker (no exit, no store) loses its lease: the
        controller kills it, re-dispatches, and still converges."""
        t = _mem(tmp_path)
        cfg = _cfg(tmp_path, transport=t, lease_s=0.3)
        probe = {}

        def factory(shard_id, attempt):
            if shard_id == 0 and attempt == 1:
                def _hang(cancelled):
                    cancelled.wait(30)
                probe["worker"] = ThreadWorker(_hang)
                return probe["worker"]
            return FleetController._default_worker(ctl, shard_id, attempt)

        ctl = FleetController(cfg, worker_factory=factory)
        rc = ctl.run(exit_when_converged=True)
        assert rc == 0 and ctl.converged
        assert ctl.metrics.get("lease_expiries_total") == 1
        assert ctl.metrics.get("shards_healed_total") == 1
        assert probe["worker"].poll() == -9  # killed, cancel signalled
        assert probe["worker"].cancelled.is_set()
        assert len(ScheduleDatabase(cfg.db)) == len(ctl.jobs)

    def test_gives_up_after_max_attempts(self, tmp_path):
        """A shard that crashes on every dispatch is eventually abandoned:
        the controller reports wedged/degraded instead of spinning."""
        t = _mem(tmp_path)
        cfg = _cfg(tmp_path, transport=t, max_attempts=2)

        def factory(shard_id, attempt):
            if shard_id == 0:
                def _boom(cancelled):
                    raise RuntimeError("always crashes")
                return ThreadWorker(_boom)
            return FleetController._default_worker(ctl, shard_id, attempt)

        ctl = FleetController(cfg, worker_factory=factory)
        rc = ctl.run(exit_when_converged=True)
        assert rc == 1 and ctl.wedged and not ctl.converged
        assert ctl.given_up == {0}
        assert ctl.attempts[0] == 2
        assert ctl.health()["status"] == "degraded"
        # the healthy shard's records still made it into the store
        assert len(ScheduleDatabase(cfg.db)) == ctl._shard_jobs[1]

    def test_resume_skips_published_shards(self, tmp_path):
        """A restarted controller treats published shard stores as done
        (the store/manifest is the commit marker, as sync sees it) and
        reconverges without re-tuning anything."""
        t = _mem(tmp_path)
        first = FleetController(_cfg(tmp_path, transport=t))
        assert first.run(exit_when_converged=True) == 0

        second = FleetController(_cfg(tmp_path, transport=t))
        assert second.done == {0, 1}
        assert second.run(exit_when_converged=True) == 0
        assert second.converged
        assert second.metrics.get("jobs_dispatched_total") == 0
        resumed = [e for e in second.events if e["event"] == "resumed"]
        assert len(resumed) == 2


# -- HTTP API ---------------------------------------------------------------

def _get(port, path):
    with urllib.request.urlopen(
            f"http://127.0.0.1:{port}{path}", timeout=10) as resp:
        return resp.status, resp.read().decode("utf-8")


def _get_err(port, path):
    try:
        return _get(port, path)
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode("utf-8")


@pytest.fixture()
def served(tmp_path):
    """A converged controller with its HTTP API bound to an OS-chosen
    port."""
    t = _mem(tmp_path)
    ctl = FleetController(_cfg(tmp_path, transport=t))
    assert ctl.run(exit_when_converged=True) == 0
    server = start_http(ctl)
    try:
        yield ctl, server.server_address[1]
    finally:
        server.shutdown()
        server.server_close()


class TestHttpApi:
    def test_healthz(self, served):
        ctl, port = served
        status, body = _get(port, "/healthz")
        assert status == 200
        health = json.loads(body)
        assert health["status"] == "ok" and health["converged"] is True
        assert health["shards"]["done"] == 2
        assert health["snapshot"]["sha1"] == ctl._snapshot_info.sha1
        assert health["snapshot"]["built_at"] is not None

    def test_metrics_exposes_acceptance_series(self, served):
        ctl, port = served
        status, body = _get(port, "/metrics")
        assert status == 200
        # the acceptance-named series, with values
        assert f"tuna_jobs_done_total {len(ctl.jobs)}" in body
        assert "tuna_jobs_healed_total 0" in body
        assert "tuna_store_lag_seconds " in body
        assert "tuna_snapshot_age_seconds " in body
        assert "tuna_sync_divergence 0" in body
        assert f"tuna_store_records {len(ctl.jobs)}" in body
        assert f'sha1="{ctl._snapshot_info.sha1}"' in body
        # age/lag gauges are live (positive once converged, never -1 here)
        for line in body.splitlines():
            if line.startswith(("tuna_store_lag_seconds ",
                                "tuna_snapshot_age_seconds ")):
                assert float(line.split()[-1]) >= 0
        # every SPEC series renders with HELP + TYPE
        for name, kind, _ in ControllerMetrics.SPEC:
            assert f"# TYPE tuna_{name} {kind}" in body

    def test_schedule_matches_query_json(self, served, capsys):
        """The /schedule endpoint and `query --json` share one serializer:
        byte-identical record objects for the same filter."""
        ctl, port = served
        status, body = _get(port, "/schedule?op=matmul&target=tpu_v5e")
        assert status == 200
        obj = json.loads(body)
        assert obj["count"] == len(obj["records"]) > 0
        assert obj["snapshot_sha1"] == ctl._snapshot_info.sha1
        assert obj["cost_model_version"] == COST_MODEL_VERSION

        rc = cli.main(["query", "--db", ctl.cfg.db, "--op", "matmul",
                       "--target", "tpu_v5e", "--json"])
        assert rc == 0
        assert json.loads(capsys.readouterr().out) == obj["records"]

    def test_schedule_no_match_is_404(self, served):
        _, port = served
        status, body = _get_err(port, "/schedule?op=nope%5B")
        assert status == 404 and "no matching" in body

    def test_unknown_route_is_404(self, served):
        _, port = served
        status, body = _get_err(port, "/nope")
        assert status == 404 and "/schedule" in body

    def test_schedule_before_first_snapshot_is_503(self, tmp_path):
        ctl = FleetController(_cfg(tmp_path))
        server = start_http(ctl)
        try:
            port = server.server_address[1]
            status, body = _get_err(port, "/schedule?op=matmul")
            assert status == 503 and "no snapshot" in body
        finally:
            server.shutdown()
            server.server_close()


# -- query --json (CLI satellite) ------------------------------------------

class TestQueryJson:
    def test_json_flag_emits_record_to_dict(self, tmp_path, capsys):
        db_path = str(tmp_path / "db.jsonl")
        db = ScheduleDatabase(db_path)
        db.add(_rec(op="matmul[a]", score=2.0, meta={"strategy": "x"}))
        db.add(_rec(op="matmul[b]", score=1.0))
        assert cli.main(["query", "--db", db_path, "--json"]) == 0
        out = json.loads(capsys.readouterr().out)
        assert out == [record_to_dict(r) for r in db.query()]

    def test_json_flag_empty_is_rc1_with_empty_array(self, tmp_path,
                                                     capsys):
        db_path = str(tmp_path / "db.jsonl")
        ScheduleDatabase(db_path)
        assert cli.main(["query", "--db", db_path, "--json"]) == 1
        assert json.loads(capsys.readouterr().out) == []


# -- freshness stamps (tuned_at / built_at) --------------------------------

class TestFreshnessStamps:
    def test_new_records_carry_tuned_at(self, tmp_path):
        db = ScheduleDatabase(str(tmp_path / "db.jsonl"))
        job = orchestrator.jobs_for(["dense_256"], ["tpu_v5e"], limit=16)[0]
        before = time.time()
        rec = orchestrator.run_job(job)
        assert before - 1 <= rec.meta["tuned_at"] <= time.time() + 1
        db.add(rec)
        assert db.last_tuned_at() == rec.meta["tuned_at"]

    def test_old_records_without_stamp_still_load_and_merge(self, tmp_path):
        a = str(tmp_path / "a.jsonl")
        ScheduleDatabase(a).add(_rec(op="matmul[old]", meta={"strategy":
                                                             "x"}))
        db = ScheduleDatabase(str(tmp_path / "b.jsonl"))
        db.merge(a)
        assert db.last_tuned_at() is None
        assert db.best("matmul[old]", "tpu_v5e").meta["strategy"] == "x"

    def test_tuned_at_never_decides_a_merge(self, tmp_path):
        """Two records identical but for the wall-clock stamp tie under
        the total order: the incumbent wins, so re-syncing a re-tuned
        shard stays a no-op."""
        a = str(tmp_path / "a.jsonl")
        b = str(tmp_path / "b.jsonl")
        ScheduleDatabase(a).add(_rec(meta={"tuned_at": 1000.0}))
        ScheduleDatabase(b).add(_rec(meta={"tuned_at": 2000.0}))
        db = ScheduleDatabase(str(tmp_path / "m.jsonl"))
        db.merge(a, provenance=False)
        assert db.merge(b, provenance=False) == 0
        assert db.best("matmul[x]", "tpu_v5e").meta["tuned_at"] == 1000.0

    def test_snapshot_built_at_roundtrip(self, tmp_path):
        db = ScheduleDatabase(str(tmp_path / "db.jsonl"))
        db.add(_rec())
        path = str(tmp_path / "snap.json")
        cache = ScheduleCache.from_db(db)
        cache.save(path)
        assert cache.built_at is not None
        assert read_snapshot_header(path)["built_at"] == cache.built_at
        assert ScheduleCache.load(path).built_at == cache.built_at

    def test_built_at_outside_the_content_address(self, tmp_path):
        """Rebuilding identical content later keeps the same sha1 — the
        stamp must not defeat content addressing."""
        db = ScheduleDatabase(str(tmp_path / "db.jsonl"))
        db.add(_rec())
        a = ScheduleCache.from_db(db)
        a.save(str(tmp_path / "a.json"))
        time.sleep(0.01)
        b = ScheduleCache.from_db(db)
        b.save(str(tmp_path / "b.json"))
        assert a.sha1 == b.sha1

    def test_old_snapshot_without_built_at_still_loads(self, tmp_path):
        db = ScheduleDatabase(str(tmp_path / "db.jsonl"))
        db.add(_rec())
        path = str(tmp_path / "snap.json")
        ScheduleCache.build(db, path)
        with open(path, "r", encoding="utf-8") as f:
            obj = json.load(f)
        del obj["built_at"]  # what a pre-stamp snapshot looks like
        with open(path, "w", encoding="utf-8") as f:
            json.dump(obj, f, default=float)
        cache = ScheduleCache.load(path)
        assert cache.built_at is None
        assert len(cache) == 1

    def test_noop_ensure_preserves_original_build_stamp(self, tmp_path):
        db_path = str(tmp_path / "db.jsonl")
        ScheduleDatabase(db_path).add(_rec())
        from repro.tuna.cache import SnapshotManager

        mgr = SnapshotManager(db_path, str(tmp_path / "snaps"))
        first = mgr.ensure()
        assert first.rebuilt and first.built_at is not None
        time.sleep(0.02)
        again = mgr.ensure()
        assert not again.rebuilt
        assert again.sha1 == first.sha1
        assert again.built_at == first.built_at
