"""Distribution layer: HLO collective parsing (incl. loop scaling), sharding
rules, elastic re-shard, and an in-process mini multi-pod dry-run (8 host
devices via subprocess — device count is locked at jax init, so these run in
a child interpreter)."""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.hlo_features import (
    loop_scaled_collectives,
    parse_collectives,
    parse_hlo,
)


class TestHloParsing:
    HLO = textwrap.dedent("""
        %add { ... }

        %body.1 (p: (s32[], f32[128,64])) -> (s32[], f32[128,64]) {
          %ar = f32[128,64]{1,0} all-reduce(%x), replica_groups=[2,4]<=[8], to_apply=%add
          %ag = f32[64,64]{1,0} all-gather(%y), replica_groups=[4,2]<=[8]T(1,0)
        }

        %cond.1 (p: (s32[], f32[128,64])) -> pred[] {
          %c = s32[] constant(12)
          ROOT %lt = pred[] compare(%i, %c), direction=LT
        }

        ENTRY %main (a: f32[128,64]) -> f32[128,64] {
          %w = (s32[], f32[128,64]) while(%init), condition=%cond.1, body=%body.1
          %ar2 = f32[32,32]{1,0} all-reduce(%z), replica_groups=[8,1]<=[8], to_apply=%add
        }
    """)

    def test_unscaled_counts_and_bytes(self):
        st = parse_collectives(self.HLO)
        assert st.counts["all-reduce"] == 2
        assert st.counts["all-gather"] == 1
        assert st.operand_bytes["all-reduce"] == 128 * 64 * 4 + 32 * 32 * 4
        # [4,2]<=[8] = 4 groups of size 2: operand = result / 2
        assert st.operand_bytes["all-gather"] == 64 * 64 * 4 / 2

    def test_loop_scaling_multiplies_body(self):
        st = loop_scaled_collectives(self.HLO)
        assert st.counts["all-reduce"] == 12 + 1
        assert st.operand_bytes["all-reduce"] == pytest.approx(
            12 * 128 * 64 * 4 + 32 * 32 * 4)
        assert st.operand_bytes["all-gather"] == pytest.approx(
            12 * 64 * 64 * 4 / 2)

    def test_ring_link_bytes_model(self):
        st = parse_collectives(self.HLO)
        # all-reduce over group of 4: 2*(s-1)/s * bytes
        first = 2 * (4 - 1) / 4 * 128 * 64 * 4
        second = 2 * (1 - 1) / 1 * 32 * 32 * 4
        assert st.link_bytes["all-reduce"] == pytest.approx(first + second)

    def test_done_halves_not_double_counted(self):
        txt = ("%s = f32[16,16]{1,0} all-reduce-start(%x), replica_groups=[2,2]<=[4]\n"
               "%d = f32[16,16]{1,0} all-reduce-done(%s)\n")
        st = parse_collectives(txt)
        assert st.counts["all-reduce"] == 1


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses
import jax
from repro.configs.base import get_config
from repro.launch import mesh as mesh_mod
from repro.launch.dryrun import run_cell

cfg = get_config("{arch}").reduced()
mesh = mesh_mod.make_mesh({mesh_shape}, {axes})
rec = run_cell("{arch}", "{shape}", mesh=mesh, cfg=cfg, verbose=False)
print("RESULT::" + json.dumps({{k: rec[k] for k in ("status", "n_devices")}}))
"""


def _run_mini(arch, shape, mesh_shape, axes):
    code = MINI_DRYRUN.format(arch=arch, shape=shape, mesh_shape=mesh_shape,
                              axes=axes)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/tmp", "JAX_PLATFORMS": "cpu"},
        timeout=420,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT::")][-1]
    return json.loads(line.split("RESULT::", 1)[1])


@pytest.mark.slow
class TestMiniDryRun:
    """Reduced configs on small meshes — structure identical to production
    (same run_cell path: shardings, accum, SP, loop-scaled parsing)."""

    def test_single_pod_2x4(self):
        rec = _run_mini("yi_6b", "train_4k", (2, 4), ("data", "model"))
        assert rec["status"] == "ok" and rec["n_devices"] == 8

    def test_multi_pod_2x2x2(self):
        rec = _run_mini("yi_6b", "train_4k", (2, 2, 2),
                        ("pod", "data", "model"))
        assert rec["status"] == "ok" and rec["n_devices"] == 8

    def test_moe_arch_2x4(self):
        rec = _run_mini("qwen3_moe_235b_a22b", "train_4k", (2, 4),
                        ("data", "model"))
        assert rec["status"] == "ok"


class TestShardingRules:
    def test_divisibility_guard(self):
        import jax
        from repro.parallel.sharding import _guard

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 4, "model": 2}

        m = FakeMesh()
        spec = _guard(("data", "model"), (8, 6), m)
        assert tuple(spec) == ("data", "model")
        spec = _guard(("data", "model"), (6, 6), m)  # 6 % 4 != 0
        assert tuple(spec) == (None, "model")

    def test_head_aware_overrides(self):
        from repro.configs.base import get_config
        from repro.parallel.sharding import head_aware_overrides

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        ov = head_aware_overrides(get_config("yi_6b"), FakeMesh())
        assert "wk" in ov and "wq" not in ov  # kv=4 replicated, 32 heads ok
        ov = head_aware_overrides(get_config("qwen25_14b"), FakeMesh())
        assert "wq" in ov  # 40 heads don't divide 16
        ov = head_aware_overrides(get_config("stablelm_3b"), FakeMesh())
        assert ov == {}  # 32/32 fully shardable
