"""Evolution Strategies (Alg. 4) + tuner end-to-end."""
import numpy as np
import pytest

from repro.core import MatmulSpace, evolve, rank_space, tune
from repro.core.tuner import tuned_matmul_blocks
from repro.hw import get_target

TPU = get_target("tpu_v5e")


class TestES:
    def test_optimizes_quadratic(self):
        target = np.array([1.5, -2.0, 0.5])

        def fitness(theta):
            return -float(np.sum((theta - target) ** 2))

        res = evolve(fitness, dim=3, iterations=40, population=24, seed=0)
        assert res.best_fitness > -0.5
        assert np.allclose(res.best_theta, target, atol=1.0)

    def test_deterministic_given_seed(self):
        def fitness(theta):
            return -float(np.sum(theta ** 2))

        a = evolve(fitness, dim=4, iterations=5, population=8, seed=7)
        b = evolve(fitness, dim=4, iterations=5, population=8, seed=7)
        assert np.allclose(a.best_theta, b.best_theta)
        assert a.best_fitness == b.best_fitness

    def test_history_monotone(self):
        def fitness(theta):
            return -float(np.sum(theta ** 2))

        res = evolve(fitness, dim=2, iterations=10, population=8, seed=1)
        assert all(a <= b + 1e-12 for a, b in zip(res.history, res.history[1:]))


class TestTuner:
    def test_es_matches_exhaustive_on_small_space(self):
        space = MatmulSpace(1024, 1024, 1024, 2, target_kind="tpu")
        exhaustive = rank_space(space, TPU, limit=1024)
        res = tune(space, TPU, iterations=12, population=16, seed=0)
        best_exhaustive = exhaustive[0][1]
        # ES should land within 25% of the global optimum's score
        assert res.score <= best_exhaustive * 1.25
        assert res.score <= res.default_score  # never worse than default

    def test_vmem_constraint_respected(self):
        space = MatmulSpace(4096, 4096, 4096, 2, target_kind="tpu")
        ranked = rank_space(space, TPU, limit=1024)
        cfg = ranked[0][0]
        tile = (cfg["bm"] * cfg["bk"] + cfg["bk"] * cfg["bn"]
                + cfg["bm"] * cfg["bn"]) * 2
        bufs = 2 if cfg["double_buffer"] else 1
        assert tile * bufs <= TPU.fast_mem_bytes

    def test_tuned_blocks_divide_shape(self):
        bm, bn, bk = tuned_matmul_blocks(2048, 2048, 2048, 2)
        assert 2048 % bm == 0 and 2048 % bn == 0 and 2048 % bk == 0
        # hardware-aligned tiles
        assert bn % 128 == 0 and bk % 128 == 0

    def test_ranking_penalises_misaligned_tiles(self):
        """8-wide M tiles waste 15/16 of the MXU; the model must rank a
        128-aligned tile above them."""
        space = MatmulSpace(2048, 2048, 2048, 2, target_kind="tpu")
        ranked = rank_space(space, TPU, limit=1024)
        best = ranked[0][0]
        assert best["bm"] >= 128
