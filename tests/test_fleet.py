"""Distributed tuning fleet: deterministic sharding, store sync, the
serving snapshot cache, cross-process store hardening, retry accounting.

The acceptance spine: a 3-shard fleet run + ``sync`` + ``snapshot`` must
yield the same best-record set as a single-process run over the same job
matrix — record for record, with only per-shard provenance added.

This module is imported by spawned worker processes (the stress and retry
tests), so it must stay jax-free: everything here is numpy-backed.
"""
import json
import multiprocessing
import os

import pytest

from repro.tuna import fleet, orchestrator
from repro.tuna.cache import ScheduleCache
from repro.tuna.db import ScheduleDatabase, ScheduleRecord, strip_bookkeeping
from repro.tuna.orchestrator import TuneJob

# ops × targets × strategies; dense_256@tpu_v5e appears under both
# strategies, so sync must also resolve a same-key conflict
JOB_OPS = ["dense_256", "dense_512", "batch_matmul", "depthwise_conv2d"]
JOB_TARGETS = ["tpu_v5e", "cpu_avx2"]


def _matrix():
    jobs = orchestrator.jobs_for(JOB_OPS, JOB_TARGETS, limit=64)
    jobs += orchestrator.jobs_for(["dense_256"], ["tpu_v5e"],
                                  strategy="es", limit=64)
    return jobs


def _strip(db):
    """Best records as comparable tuples, bookkeeping meta removed."""
    return [
        (r.op, r.target, r.version, json.dumps(r.config, sort_keys=True),
         r.score, r.evaluations, strip_bookkeeping(r.meta))
        for r in db.records()
    ]


class TestShardJobs:
    @pytest.mark.parametrize("num_shards", [1, 2, 3, 5])
    def test_disjoint_and_covering(self, num_shards):
        jobs = _matrix()
        shards = [fleet.shard_jobs(jobs, num_shards, i)
                  for i in range(num_shards)]
        assert sum(len(s) for s in shards) == len(jobs)
        seen = [fleet.job_fingerprint(j) for s in shards for j in s]
        assert sorted(seen) == sorted(fleet.job_fingerprint(j) for j in jobs)
        assert len(set(seen)) == len(jobs)  # pairwise disjoint

    def test_stable_across_runs_and_list_order(self):
        jobs = _matrix()
        a = fleet.shard_jobs(jobs, 3, 1)
        b = fleet.shard_jobs(list(reversed(jobs)), 3, 1)
        assert sorted(map(fleet.job_fingerprint, a)) == \
            sorted(map(fleet.job_fingerprint, b))
        assert fleet.shard_jobs(jobs, 3, 1) == a  # re-run: identical slice

    def test_invalid_arguments_rejected(self):
        with pytest.raises(ValueError):
            fleet.shard_jobs([], 0, 0)
        with pytest.raises(ValueError):
            fleet.shard_jobs([], 2, 2)

    def test_shard_store_path_derivation(self):
        assert fleet.shard_store_path("db.jsonl", 3) == "db.shard03.jsonl"
        assert fleet.shard_store_path("/x/store", 0) == "/x/store.shard00.jsonl"


class TestFleetEndToEnd:
    def test_three_shard_fleet_matches_single_run(self, tmp_path):
        """Acceptance: shard → tune → sync → snapshot reproduces the
        single-process store record-for-record, including the crash-one-
        shard-then-resume path and idempotent re-sync."""
        jobs = _matrix()
        single = ScheduleDatabase(tmp_path / "single.jsonl")
        assert orchestrator.run(jobs, db=single, workers=1).ok

        base = str(tmp_path / "fleet.jsonl")
        # shard 2's host "crashes" before tuning: only 0 and 1 run
        partial_run = fleet.run_fleet(jobs, 3, base, workers=1,
                                      shard_ids=[0, 1])
        assert partial_run.ok
        assert all(s.jobs > 0 for s in partial_run.shards)
        partial = fleet.sync(base, 3)
        assert [os.path.basename(p) for p in partial.skipped] == \
            ["fleet.shard02.jsonl"]
        assert 0 < partial.keys < len(single)

        # the host comes back and re-runs its shard; sync completes
        resumed = fleet.run_shard(jobs, 3, 2, base, workers=1)
        assert resumed.ok and resumed.jobs > 0
        full = fleet.sync(base, 3)
        assert not full.skipped

        assert fleet.divergence(full.db, single, "fleet", "single") == []
        assert _strip(full.db) == _strip(single)
        # per-shard provenance is stamped on every merged record
        origins = {r.meta["provenance"] for r in full.db.records()}
        assert origins <= {f"fleet.shard0{i}.jsonl" for i in range(3)}
        assert len(origins) == 3

        # re-running a shard and re-syncing is a no-op (idempotence)
        before = open(base, "rb").read()
        fleet.run_shard(jobs, 3, 1, base, workers=1)
        fleet.sync(base, 3)
        assert open(base, "rb").read() == before

        # snapshot serves the merged store verbatim
        snap = str(tmp_path / "cache.json")
        ScheduleCache.build(base, snap)
        cache = ScheduleCache.load(snap)
        assert cache.records() == full.db.records()


class TestSyncEdgeCases:
    def test_empty_shard_still_leaves_a_store(self, tmp_path):
        """A shard whose slice of the matrix is empty must not look like a
        crashed shard forever: run_shard touches the store file even when
        there is nothing to do, so sync reports nothing skipped."""
        jobs = orchestrator.jobs_for(["dense_256"], ["tpu_v5e"], limit=64)
        base = str(tmp_path / "fleet.jsonl")
        rep = fleet.run_fleet(jobs, 2, base, workers=1)  # 1 job, 2 shards
        assert rep.ok and sorted(s.jobs for s in rep.shards) == [0, 1]
        srep = fleet.sync(base, 2)
        assert srep.skipped == [] and srep.keys == 1

    def test_provenance_never_decides_a_tie(self, tmp_path):
        """Score ties must resolve identically with and without provenance
        stamping — the shard a record travelled through is bookkeeping,
        not a tie-breaker — so `sync --verify` can't diverge on it."""
        recs = [
            ScheduleRecord(op="a[]", target="t0", config={"bm": 256},
                           score=1.0, meta={"strategy": "es"}),
            ScheduleRecord(op="a[]", target="t0", config={"bm": 64},
                           score=1.0, meta={"strategy": "exhaustive"}),
        ]
        paths = []
        for i, rec in enumerate(recs):
            db = ScheduleDatabase(tmp_path / f"s{i}.jsonl")
            db.add(rec)
            paths.append(db.path)
        winners = set()
        for name, order, prov in [("ab", paths, True),
                                  ("ba", paths[::-1], True),
                                  ("np", paths, False)]:
            db = ScheduleDatabase(tmp_path / f"{name}.jsonl")
            db.merge_all(order, provenance=prov)
            winners.add(json.dumps(db.best("a[]", "t0").config))
        assert len(winners) == 1


class TestScheduleCache:
    def _populated_db(self, tmp_path):
        db = ScheduleDatabase(tmp_path / "db.jsonl")
        for op, target, version, score in [
            ("matmul[K=256,M=256,N=256,dtype_bytes=2]", "tpu_v5e", "cm1", 2.0),
            ("matmul[K=256,M=256,N=256,dtype_bytes=2]", "tpu_v5e", "cm1", 1.0),
            ("matmul[K=512,M=512,N=512,dtype_bytes=2]", "cpu_avx2", "cm1", 3.0),
            ("matmul[K=512,M=512,N=512,dtype_bytes=2]", "cpu_avx2",
             "cm1-cal-deadbeef", 4.0),
            ("flash[d=128,dtype_bytes=2,s=1024]", "tpu_v5e", "cm1", 5.0),
        ]:
            db.add(ScheduleRecord(op=op, target=target, version=version,
                                  config={"bm": 128}, score=score,
                                  meta={"strategy": "exhaustive"}))
        return db

    def test_snapshot_roundtrip_matches_live_db(self, tmp_path):
        db = self._populated_db(tmp_path)
        out = str(tmp_path / "cache.json")
        built = ScheduleCache.build(db.path, out)
        loaded = ScheduleCache.load(out)
        assert len(loaded) == len(built) == len(db)
        for rec in db.records():  # best() parity for every key
            assert loaded.best(rec.op, rec.target, rec.version) == rec
        for kw in ({}, {"op": "matmul"}, {"target": "cpu_avx2"},
                   {"version": "cm1-cal-deadbeef"},
                   {"op": "flash", "target": "tpu_v5e"}):
            assert loaded.query(**kw) == db.query(**kw)
        assert loaded.hits == len(db) and loaded.misses == 0
        assert loaded.best("nope[]", "tpu_v5e") is None
        assert loaded.misses == 1

    def test_rebuilt_snapshot_reinstall_serves_new_records(self, tmp_path):
        """Regression: the per-path snapshot instances in core.tuner must
        revalidate by stat — re-tuning + rebuilding a snapshot at the same
        path, then re-installing it, has to serve the *new* records (a
        snapshot is immutable, so a stale cached instance never heals)."""
        from repro.core import tuner

        db = ScheduleDatabase(tmp_path / "db.jsonl")
        op = "matmul[K=256,M=256,N=256,dtype_bytes=2]"
        db.add(ScheduleRecord(op=op, target="tpu_v5e",
                              config={"bm": 64}, score=2.0))
        snap = str(tmp_path / "cache.json")
        ScheduleCache.build(db.path, snap)
        tuner.set_default_cache(snap)
        assert tuner.get_default_cache().best(op, "tpu_v5e").config == \
            {"bm": 64}

        db.add(ScheduleRecord(op=op, target="tpu_v5e",
                              config={"bm": 128}, score=1.0))  # re-tuned
        ScheduleCache.build(db.path, snap)
        tuner.set_default_cache(snap)
        assert tuner.get_default_cache().best(op, "tpu_v5e").config == \
            {"bm": 128}

    def test_cache_is_immutable(self, tmp_path):
        db = self._populated_db(tmp_path)
        cache = ScheduleCache.from_db(db)
        with pytest.raises(TypeError, match="immutable"):
            cache.add(db.records()[0])

    def test_corrupt_snapshot_rejected(self, tmp_path):
        db = self._populated_db(tmp_path)
        out = str(tmp_path / "cache.json")
        ScheduleCache.build(db.path, out)
        blob = open(out).read()
        with open(out, "w") as f:  # flip a stored score: digest must catch it
            f.write(blob.replace('"score": 5.0', '"score": 0.5'))
        with pytest.raises(ValueError, match="digest mismatch"):
            ScheduleCache.load(out)
        with open(out, "w") as f:
            f.write(json.dumps({"schema": "something-else", "records": []}))
        with pytest.raises(ValueError, match="not a schedule snapshot"):
            ScheduleCache.load(out)


# -- cross-process stress (the inode-revalidation path in db.py) ----------

def _stress_worker(path: str, wid: int, n: int) -> None:
    """Interleave appends and compactions against a shared store."""
    db = ScheduleDatabase(path)
    for i in range(n):
        db.add(ScheduleRecord(op=f"op{i % 5}[]", target=f"t{wid}",
                              config={"i": i}, score=float(n - i)))
        if i % 7 == 3:
            db.compact()


class TestCrossProcessStress:
    def test_concurrent_add_and_compact(self, tmp_path):
        """4 processes interleaving add+compact on one store: no torn
        lines, no lost best records (exercises the fd-inode revalidation
        in ``_append_locked``/``compact``)."""
        path = str(tmp_path / "db.jsonl")
        n = 30
        ctx = multiprocessing.get_context("spawn")
        procs = [ctx.Process(target=_stress_worker, args=(path, wid, n))
                 for wid in range(4)]
        for p in procs:
            p.start()
        for p in procs:
            p.join(timeout=120)
            assert p.exitcode == 0
        db = ScheduleDatabase(path)
        assert db.corrupt_lines == 0
        for wid in range(4):
            for k in range(5):
                idxs = [i for i in range(n) if i % 5 == k]
                best = db.best(f"op{k}[]", f"t{wid}")
                assert best is not None, (wid, k)
                assert best.score == float(n - max(idxs))


# -- retry accounting (regression: attempts keyed by frozen TuneJob) ------

_FLAKY_DIR_ENV = "REPRO_TEST_FLAKY_DIR"


def _flaky_runner(job: TuneJob) -> ScheduleRecord:
    """Fails the first two executions fleet-wide (cross-process markers),
    then succeeds — a transient-infrastructure stand-in."""
    d = os.environ[_FLAKY_DIR_ENV]
    for i in range(2):
        try:
            fd = os.open(os.path.join(d, f"fail{i}"),
                         os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            continue
        os.close(fd)
        raise RuntimeError(f"transient failure {i}")
    return ScheduleRecord(op=f"flaky[{job.op}]", target=job.target,
                          config={}, score=1.0)


def _always_failing_runner(job: TuneJob) -> ScheduleRecord:
    """Every execution drops a unique marker file, then fails — so the
    total execution count is observable across processes."""
    d = os.environ[_FLAKY_DIR_ENV]
    for k in range(1000):
        try:
            fd = os.open(os.path.join(d, f"exec{k}"),
                         os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            continue
        os.close(fd)
        raise RuntimeError(f"execution {k} failed")
    raise AssertionError("marker space exhausted")


class TestRetryAccounting:
    def test_duplicate_jobs_do_not_share_retry_budget(self, tmp_path,
                                                      monkeypatch):
        """Two *identical* (frozen, equal) jobs must each get their own
        full retry budget: 2 jobs × (1 + 2 retries) = 6 executions.
        Keying attempts by the job value made duplicates share one counter
        and exhaust each other's retries (4 executions, lost attempts)."""
        monkeypatch.setenv(_FLAKY_DIR_ENV, str(tmp_path))
        jobs = [TuneJob(op="dense_256"), TuneJob(op="dense_256")]
        report = orchestrator.run(jobs, workers=2, retries=2,
                                  runner=_always_failing_runner)
        executions = [f for f in os.listdir(tmp_path)
                      if f.startswith("exec")]
        assert len(executions) == 6
        assert len(report.failures) == 2
        assert [f.attempts for f in report.failures] == [3, 3]

    def test_inline_path_retries_each_duplicate(self, tmp_path, monkeypatch):
        # inline runs jobs sequentially, so the first job eats both
        # transient failures itself: it needs both of its extra attempts
        monkeypatch.setenv(_FLAKY_DIR_ENV, str(tmp_path))
        jobs = [TuneJob(op="dense_256"), TuneJob(op="dense_256")]
        report = orchestrator.run(jobs, workers=1, retries=2,
                                  runner=_flaky_runner)
        assert report.ok and len(report.records) == 2
