"""Golden releases: regression gate, waivers, AOT kernel bundles, serve
parity, and the compact/export whole-store guards that make blessing a
release trustworthy."""
import dataclasses
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import tuner
from repro.kernels import ops, ref
from repro.tuna import cli
from repro.tuna.cache import (ScheduleCache, StaleSnapshotError,
                              StaleSnapshotWarning)
from repro.tuna.db import ScheduleDatabase, ScheduleRecord
from repro.tuna.golden import (
    BundleError,
    GoldenError,
    GoldenManager,
    GoldenRegressionError,
    KernelBundle,
    build_kernel_bundle,
    plan_bundle_entries,
)
from repro.tuna.transport import MemoryTransport

MM_OP = "matmul[K=128,M=128,N=128,dtype_bytes=4]"
FL_OP = "flash[d=64,dtype_bytes=4,s=128]"
TGT = "tpu_v5e"
RNG = np.random.default_rng(3)


def mk_records(mm_score=1e-6, fl_score=2e-6, with_flash=True,
               with_conv=True):
    recs = [ScheduleRecord(op=MM_OP, target=TGT, score=mm_score,
                           config={"bm": 64, "bn": 64, "bk": 64})]
    if with_flash:
        recs.append(ScheduleRecord(op=FL_OP, target=TGT, score=fl_score,
                                   config={"block_q": 64, "block_k": 64}))
    if with_conv:
        # rides in the schedule index but has no Pallas kernel to AOT
        recs.append(ScheduleRecord(op="conv2d[foo=1]", target=TGT,
                                   config={"x": 1}, score=3e-6))
    return recs


def _mem(tmp_path) -> MemoryTransport:
    bucket = f"golden-{os.path.basename(tmp_path)}"
    MemoryTransport.wipe(bucket)
    return MemoryTransport(bucket)


class TestGoldenLifecycle:
    def test_promote_reload_and_noop_repromote(self, tmp_path):
        mgr = GoldenManager(str(tmp_path))
        info = mgr.promote(mk_records(), TGT, source="unit")
        assert info.rebuilt and info.repointed
        assert info.predecessor is None and info.count == 3
        assert os.path.exists(info.path) and os.path.exists(info.latest)
        hdr, records = mgr.load_release(info.latest)  # follows the pointer
        assert hdr["sha1"] == info.sha1 and len(records) == 3
        assert hdr["source"] == "unit"
        again = mgr.promote(mk_records(), TGT)
        assert not again.rebuilt and not again.repointed
        assert again.name == info.name

    def test_improvement_promotes_and_links_predecessor(self, tmp_path):
        mgr = GoldenManager(str(tmp_path))
        first = mgr.promote(mk_records(mm_score=2e-6), TGT)
        second = mgr.promote(mk_records(mm_score=1e-6), TGT)
        assert second.rebuilt and second.name != first.name
        assert second.predecessor == first.name
        assert second.gated_against == 3
        hdr, _ = mgr.load_release(second.path)
        assert hdr["predecessor"] == first.name
        assert mgr.current(TGT)["release"] == second.name

    def test_gate_refuses_slower_schedule(self, tmp_path):
        mgr = GoldenManager(str(tmp_path))
        first = mgr.promote(mk_records(mm_score=1e-6), TGT)
        with pytest.raises(GoldenRegressionError) as ei:
            mgr.promote(mk_records(mm_score=5e-6), TGT)
        (reg,) = ei.value.regressions
        assert reg.kind == "slower" and reg.op == MM_OP
        assert reg.old_score == 1e-6 and reg.new_score == 5e-6
        # refused promotion must leave the blessed pointer untouched
        assert mgr.current(TGT)["release"] == first.name

    def test_gate_refuses_lost_coverage(self, tmp_path):
        mgr = GoldenManager(str(tmp_path))
        mgr.promote(mk_records(), TGT)
        with pytest.raises(GoldenRegressionError) as ei:
            mgr.promote(mk_records(with_flash=False), TGT)
        (reg,) = ei.value.regressions
        assert reg.kind == "lost" and reg.op == FL_OP

    def test_waiver_promotes_and_is_recorded(self, tmp_path):
        mgr = GoldenManager(str(tmp_path))
        mgr.promote(mk_records(mm_score=1e-6), TGT)
        spec = f"{MM_OP}@{TGT}"
        info = mgr.promote(mk_records(mm_score=5e-6), TGT, waive=[spec])
        assert len(info.waived) == 1 and info.waived[0].waived_by == spec
        hdr, _ = mgr.load_release(info.path)
        (w,) = hdr["waivers"]  # the audit trail the ISSUE demands
        assert w["waived_by"] == spec and w["kind"] == "slower"
        assert w["old_score"] == 1e-6 and w["new_score"] == 5e-6

    def test_waiver_does_not_cover_other_regressions(self, tmp_path):
        mgr = GoldenManager(str(tmp_path))
        mgr.promote(mk_records(), TGT)
        with pytest.raises(GoldenRegressionError) as ei:
            mgr.promote(mk_records(mm_score=5e-6, with_flash=False), TGT,
                        waive=[f"{MM_OP}@{TGT}"])
        (reg,) = ei.value.regressions  # matmul waived, flash loss still blocks
        assert reg.op == FL_OP and reg.kind == "lost"

    def test_cost_model_bump_starts_fresh_lineage(self, tmp_path,
                                                  monkeypatch):
        mgr = GoldenManager(str(tmp_path))
        mgr.promote(mk_records(mm_score=1e-6), TGT)
        monkeypatch.setattr("repro.tuna.golden.COST_MODEL_VERSION", "cm99")
        recs = [dataclasses.replace(r, version="cm99")
                for r in mk_records(mm_score=9e-6)]
        info = mgr.promote(recs, TGT)  # slower, but scores aren't comparable
        assert info.predecessor is None and info.gated_against == 0
        assert ".cm99-" in info.name

    def test_corrupt_release_refused(self, tmp_path):
        mgr = GoldenManager(str(tmp_path))
        info = mgr.promote(mk_records(), TGT)
        obj = json.load(open(info.path))
        obj["records"][0]["score"] = 0.5  # tamper past the gate
        json.dump(obj, open(info.path, "w"))
        with pytest.raises(GoldenError, match="digest mismatch"):
            mgr.load_release(info.path)

    def test_nothing_to_promote(self, tmp_path):
        mgr = GoldenManager(str(tmp_path))
        with pytest.raises(GoldenError, match="nothing to promote"):
            mgr.promote(mk_records(), "tpu_v4")  # no records for the target


@pytest.fixture(scope="module")
def built_bundle(tmp_path_factory):
    """One promoted golden + AOT bundle shared by the read-only tests."""
    d = str(tmp_path_factory.mktemp("bundle"))
    mgr = GoldenManager(d)
    info = mgr.promote(mk_records(), TGT, source="fixture")
    _, release = mgr.load_release(info.path)
    binfo = build_kernel_bundle(release, d, TGT, golden_name=info.name)
    return mgr, info, binfo


class TestKernelBundle:
    def test_plan_partitions_records(self):
        plans, skipped = plan_bundle_entries(mk_records())
        assert sorted(p.kernel for p in plans) == ["flash", "matmul"]
        (skip,) = skipped
        assert skip[0] == "conv2d[foo=1]" and "no Pallas kernel" in skip[1]

    def test_build_load_execute(self, built_bundle):
        _, info, binfo = built_bundle
        assert binfo.entries == 2 and binfo.schedules == 3
        bundle = KernelBundle.load(binfo.path)
        assert len(bundle) == 2 and bundle.golden == info.name
        x = jnp.asarray(RNG.standard_normal((128, 128)), jnp.float32)
        y = jnp.asarray(RNG.standard_normal((128, 128)), jnp.float32)
        fn = bundle.executable("matmul", (x, y))
        assert fn is not None
        np.testing.assert_allclose(np.asarray(fn(x, y)),
                                   np.asarray(x) @ np.asarray(y),
                                   rtol=1e-5, atol=1e-4)
        q = jnp.asarray(RNG.standard_normal((1, 1, 128, 64)), jnp.float32)
        att = bundle.executable(
            "flash", (q, q, q), {"causal": True, "scale": 64 ** -0.5})
        assert att is not None
        np.testing.assert_allclose(
            np.asarray(att(q, q, q)),
            np.asarray(ref.attention(q, q, q, causal=True)),
            rtol=1e-5, atol=1e-4)
        assert bundle.exec_hits == 2
        # unknown shape -> graceful miss, caller traces normally
        small = jnp.ones((8, 8), jnp.float32)
        assert bundle.executable("matmul", (small, small)) is None
        assert bundle.exec_misses == 1

    def test_schedule_tier_and_immutability(self, built_bundle):
        _, _, binfo = built_bundle
        bundle = KernelBundle.load(binfo.path)
        rec = bundle.best(FL_OP, TGT)
        assert rec.config == {"block_q": 64, "block_k": 64}
        # the non-kernel record still rides in the schedule index
        assert bundle.best("conv2d[foo=1]", TGT) is not None
        assert bundle.best("nope[]", TGT) is None
        assert bundle.hits == 2 and bundle.misses == 1
        with pytest.raises(TypeError):
            bundle.add(None)

    def test_latest_pointer_followed(self, built_bundle):
        _, _, binfo = built_bundle
        via_ptr = KernelBundle.load(binfo.latest)
        assert via_ptr.sha1 == binfo.sha1

    def _tampered(self, binfo, tmp_path, **header_edits):
        obj = json.load(open(binfo.path))
        obj.update(header_edits)
        path = str(tmp_path / "tampered.json")
        json.dump(obj, open(path, "w"))
        return path

    def test_load_refuses_torn_copy(self, built_bundle, tmp_path):
        _, _, binfo = built_bundle
        obj = json.load(open(binfo.path))
        obj["schedules"][0]["score"] = 0.5  # payload edit breaks the digest
        path = str(tmp_path / "torn.json")
        json.dump(obj, open(path, "w"))
        with pytest.raises(BundleError, match="digest mismatch"):
            KernelBundle.load(path)

    def test_load_refuses_stale_cost_model(self, built_bundle, tmp_path):
        _, _, binfo = built_bundle
        path = self._tampered(binfo, tmp_path, cost_model_version="cm0")
        with pytest.raises(StaleSnapshotError):
            KernelBundle.load(path)

    def test_load_refuses_foreign_backend(self, built_bundle, tmp_path):
        _, _, binfo = built_bundle
        path = self._tampered(binfo, tmp_path, backend="tpu")
        with pytest.raises(BundleError, match="backend"):
            KernelBundle.load(path)

    def test_load_refuses_wrong_schema(self, built_bundle, tmp_path):
        _, info, _ = built_bundle
        with pytest.raises(BundleError, match="not a kernel bundle"):
            KernelBundle.load(info.path)  # a golden release, not a bundle


class TestBundleDispatch:
    def test_zero_trace_dispatch_with_numeric_parity(self, built_bundle):
        _, _, binfo = built_bundle
        x = jnp.asarray(RNG.standard_normal((128, 128)), jnp.float32)
        y = jnp.asarray(RNG.standard_normal((128, 128)), jnp.float32)
        q = jnp.asarray(RNG.standard_normal((1, 1, 128, 64)), jnp.float32)
        # baseline: same blocks via explicit blocks=, compiled the slow way
        base_mm = np.asarray(ops.matmul(x, y, blocks=(64, 64, 64),
                                        force_pallas=True))
        base_att = np.asarray(ops.attention(q, q, q, blocks=(64, 64),
                                            force_pallas=True))
        ops.use_kernel_bundle(binfo.path)
        ops.reset_pallas_trace_counts()
        got_mm = np.asarray(ops.matmul(x, y, force_pallas=True))
        got_att = np.asarray(ops.attention(q, q, q, force_pallas=True))
        counts = ops.pallas_trace_counts()
        assert counts == {"matmul": 0, "flash": 0}  # the AOT witness
        assert ops.get_kernel_bundle().exec_hits == 2
        # identical block configs -> bitwise-identical outputs
        np.testing.assert_array_equal(got_mm, base_mm)
        np.testing.assert_array_equal(got_att, base_att)

    def test_without_bundle_first_call_traces(self):
        x = jnp.ones((128, 128), jnp.float32)
        ops.reset_pallas_trace_counts()
        ops.matmul(x, x, force_pallas=True)
        assert ops.pallas_trace_counts()["matmul"] == 1

    def test_tracer_args_fall_through_to_trace_path(self, built_bundle):
        """Under an outer jit the args are tracers — the AOT executable
        cannot serve them, and the call must still work."""
        _, _, binfo = built_bundle
        ops.use_kernel_bundle(binfo.path)
        ops.reset_pallas_trace_counts()
        x = jnp.asarray(RNG.standard_normal((128, 128)), jnp.float32)

        @jax.jit
        def f(a, b):
            return ops.matmul(a, b, force_pallas=True)

        np.testing.assert_allclose(np.asarray(f(x, x)),
                                   np.asarray(x) @ np.asarray(x),
                                   rtol=1e-5, atol=1e-4)
        assert ops.pallas_trace_counts()["matmul"] == 1  # traced normally

    def test_bundle_is_first_schedule_tier(self, built_bundle):
        _, _, binfo = built_bundle
        ops.use_kernel_bundle(binfo.path)
        assert ops.tuned_flash_blocks(128, 64, 4) == (64, 64)
        bundle = ops.get_kernel_bundle()
        assert bundle.hits >= 1
        rec, source = tuner._lookup(MM_OP, TGT, rec_version(), None)
        assert source == "bundle" and rec.score == 1e-6

    def test_env_var_fallback_and_stale_degrade(self, built_bundle,
                                                tmp_path, monkeypatch):
        _, _, binfo = built_bundle
        monkeypatch.setenv("REPRO_TUNA_BUNDLE", binfo.path)
        monkeypatch.setattr(tuner, "_DEFAULT_BUNDLE", tuner._UNSET)
        assert tuner.get_default_bundle() is not None
        # a stale bundle degrades to OFF loudly and clears the memos
        obj = json.load(open(binfo.path))
        obj["cost_model_version"] = "cm0"
        stale = str(tmp_path / "stale_bundle.json")
        json.dump(obj, open(stale, "w"))
        cleared = []
        tuner.register_memo_clearer(lambda: cleared.append(1))
        try:
            monkeypatch.setenv("REPRO_TUNA_BUNDLE", stale)
            monkeypatch.setattr(tuner, "_DEFAULT_BUNDLE", tuner._UNSET)
            with pytest.warns(StaleSnapshotWarning,
                              match="REPRO_TUNA_BUNDLE disabled"):
                assert tuner.get_default_bundle() is None
            assert cleared
        finally:
            tuner._MEMO_CLEARERS.pop()


def rec_version():
    from repro.core.cost_model import COST_MODEL_VERSION

    return COST_MODEL_VERSION


class TestStaleCacheDegradeClearsMemos:
    def test_env_cache_stale_degrade_clears_memos(self, tmp_path,
                                                  monkeypatch):
        """Regression (the PR's satellite bug): $REPRO_TUNA_CACHE degrading
        to OFF used to leave the block-spec memos warm, so shapes memoised
        under an earlier snapshot kept serving its blocks after the
        snapshot was rejected."""
        db = ScheduleDatabase(tmp_path / "db.jsonl")
        db.add(ScheduleRecord(
            op="flash[d=128,dtype_bytes=2,s=2048]", target=TGT,
            config={"block_q": 256, "block_k": 128}, score=1e-9))
        snap = str(tmp_path / "cache.json")
        ScheduleCache.build(db.path, snap)
        tuner.set_default_cache(snap)
        assert ops.tuned_flash_blocks(2048, 128) == (256, 128)  # memoised

        obj = json.load(open(snap))
        obj["cost_model_version"] = "cm0"
        stale = str(tmp_path / "stale.json")
        json.dump(obj, open(stale, "w"))
        monkeypatch.setenv("REPRO_TUNA_CACHE", stale)
        monkeypatch.setattr(tuner, "_DEFAULT_CACHE", tuner._UNSET)
        with pytest.warns(StaleSnapshotWarning,
                          match="REPRO_TUNA_CACHE disabled"):
            assert tuner.get_default_cache() is None
        # memo must have been dropped with the cache: the pick re-resolves
        # to the heuristic, not the rejected snapshot's record
        assert ops.tuned_flash_blocks(2048, 128) != (256, 128)


class TestPublishRoundtrip:
    def test_golden_and_bundle_ship_over_mem_transport(self, tmp_path):
        src = tmp_path / "src"
        dst = tmp_path / "dst"
        os.makedirs(dst)
        mgr = GoldenManager(str(src))
        info = mgr.promote(mk_records(), TGT)
        _, release = mgr.load_release(info.path)
        binfo = build_kernel_bundle(release, str(src), TGT,
                                    golden_name=info.name)
        t = _mem(tmp_path)
        manifests = mgr.publish(t, info, bundle=binfo)
        assert len(manifests) == 4  # release + pointer, bundle + pointer
        for name in t.list():
            t.pull(name, str(dst / name))
        # the pulled pointer resolves inside the destination directory
        hdr, records = GoldenManager(str(dst)).load_release(
            str(dst / os.path.basename(info.latest)))
        assert hdr["sha1"] == info.sha1 and len(records) == 3
        bundle = KernelBundle.load(str(dst / os.path.basename(binfo.latest)))
        assert bundle.sha1 == binfo.sha1 and len(bundle) == 2
        x = jnp.ones((128, 128), jnp.float32)
        assert bundle.executable("matmul", (x, x)) is not None


class TestServeParity:
    def test_serve_with_bundle_token_identical(self, tmp_path):
        """Acceptance: a bundled serve produces the exact greedy tokens of
        an unbundled serve (cold start skips compiles, never changes
        outputs)."""
        from repro.configs.base import get_config
        from repro.launch.engine import Request
        from repro.launch.serve import serve
        from repro.models.model import Model

        mgr = GoldenManager(str(tmp_path))
        info = mgr.promote(mk_records(), TGT)
        _, release = mgr.load_release(info.path)
        binfo = build_kernel_bundle(release, str(tmp_path), TGT,
                                    golden_name=info.name)

        cfg = get_config("yi_6b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(11)
        prompts = [list(rng.integers(0, cfg.vocab, 4)) for _ in range(3)]

        def run():
            reqs = [Request(i, list(p), 4) for i, p in enumerate(prompts)]
            serve(model, params, reqs, slots=2, cap=12)
            return [r.out for r in reqs]

        plain = run()
        ops.use_kernel_bundle(binfo.path)
        bundled = run()
        assert bundled == plain


class TestGoldenCLI:
    def _write_db(self, path, records):
        db = ScheduleDatabase(path)
        for r in records:
            db.add(r)
        return str(path)

    def test_cli_end_to_end_with_bundle(self, tmp_path, capsys):
        db = self._write_db(tmp_path / "db.jsonl", mk_records())
        gdir = str(tmp_path / "golden")
        assert cli.main(["golden", "--db", db, "--dir", gdir,
                         "--bundle"]) == 0
        out = capsys.readouterr().out
        assert "promoted" in out and "first release in this lineage" in out
        assert "2 AOT kernel(s) over 3 schedules" in out
        assert "no AOT kernel for conv2d[foo=1]" in out
        # re-run: content-addressed no-op, still gated against itself
        assert cli.main(["golden", "--db", db, "--dir", gdir]) == 0
        out = capsys.readouterr().out
        assert "up to date" in out and "gated against" in out
        names = os.listdir(gdir)
        assert any(n.startswith(f"golden.{TGT}.") and "latest" not in n
                   for n in names)
        assert any(n.startswith(f"bundle.{TGT}.") and "latest" not in n
                   for n in names)

    def test_cli_refuses_regression_then_waives(self, tmp_path, capsys):
        gdir = str(tmp_path / "golden")
        good = self._write_db(tmp_path / "good.jsonl", mk_records())
        assert cli.main(["golden", "--db", good, "--dir", gdir]) == 0
        capsys.readouterr()
        worse = self._write_db(tmp_path / "worse.jsonl",
                               mk_records(mm_score=5e-6))
        assert cli.main(["golden", "--db", worse, "--dir", gdir]) == 1
        err = capsys.readouterr().err
        assert "REFUSED golden promotion" in err and MM_OP in err
        assert cli.main(["golden", "--db", worse, "--dir", gdir,
                         "--waive", f"{MM_OP}@{TGT}"]) == 0
        err = capsys.readouterr().err
        assert "WAIVED" in err

    def test_cli_publish_over_mem(self, tmp_path, capsys):
        db = self._write_db(tmp_path / "db.jsonl", mk_records())
        t = _mem(tmp_path)
        url = f"mem://{t.bucket}"
        assert cli.main(["golden", "--db", db,
                         "--dir", str(tmp_path / "g"),
                         "--publish", url]) == 0
        assert "published" in capsys.readouterr().out
        assert any(n.startswith("golden.") for n in t.list())

    def test_cli_no_records_is_an_error(self, tmp_path, capsys):
        db = str(tmp_path / "empty.jsonl")
        ScheduleDatabase(db)
        assert cli.main(["golden", "--db", db,
                         "--dir", str(tmp_path / "g")]) == 2
        assert "no records" in capsys.readouterr().err


class TestCompactExportGuards:
    def _base_with_shards(self, tmp_path):
        from repro.tuna.fleet import shard_store_path

        base = str(tmp_path / "db.jsonl")
        db = ScheduleDatabase(base)
        db.add(mk_records()[0])
        shard = ScheduleDatabase(shard_store_path(base, 0))
        shard.add(mk_records(fl_score=7e-7)[1])
        return base, shard.path

    def test_compact_refuses_stale_partial_store(self, tmp_path, capsys):
        """Regression (the PR's satellite bug): compact used to silently
        rewrite the base store while fleet shards sat next to it."""
        base, _ = self._base_with_shards(tmp_path)
        assert cli.main(["compact", "--db", base]) == 2
        err = capsys.readouterr().err
        assert "per-shard store" in err and "sync" in err
        assert cli.main(["compact", "--db", base, "--ignore-shards"]) == 0

    def test_export_refuses_stale_partial_store(self, tmp_path, capsys):
        base, _ = self._base_with_shards(tmp_path)
        out = str(tmp_path / "best.json")
        assert cli.main(["export", "--db", base, "--out", out]) == 2
        assert not os.path.exists(out)
        assert cli.main(["export", "--db", base, "--out", out,
                         "--ignore-shards"]) == 0
        assert len(json.load(open(out))) == 1  # base store only, by choice

    def test_compact_with_transport_pulls_merges_pushes(self, tmp_path,
                                                        capsys):
        from repro.tuna.fleet import shard_store_path

        t = _mem(tmp_path)
        url = f"mem://{t.bucket}"
        # the fleet published two shard stores on the channel
        pub = tmp_path / "pub"
        os.makedirs(pub)
        for i, rec in enumerate(mk_records(with_conv=False)):
            p = shard_store_path(str(pub / "db.jsonl"), i)
            ScheduleDatabase(p).add(rec)
            t.push(p, os.path.basename(p))
        work = tmp_path / "work"
        os.makedirs(work)
        base = str(work / "db.jsonl")
        assert cli.main(["compact", "--db", base, "--transport", url,
                         "--num-shards", "2"]) == 0
        out = capsys.readouterr().out
        assert out.count("pulled") == 2 and "compacted" in out
        assert len(ScheduleDatabase(base)) == 2  # both shards absorbed
        # and the merged store went back on the channel under its base name
        assert "db.jsonl" in t.list()

    def test_transport_without_num_shards_fails_fast(self, tmp_path,
                                                     capsys):
        t = _mem(tmp_path)
        rc = cli.main(["export", "--db", str(tmp_path / "db.jsonl"),
                       "--out", str(tmp_path / "o.json"),
                       "--transport", f"mem://{t.bucket}"])
        assert rc == 2
        assert "--num-shards" in capsys.readouterr().err

    def test_export_with_transport_covers_the_fleet(self, tmp_path, capsys):
        from repro.tuna.fleet import shard_store_path

        t = _mem(tmp_path)
        pub = tmp_path / "pub"
        os.makedirs(pub)
        p = shard_store_path(str(pub / "db.jsonl"), 0)
        ScheduleDatabase(p).add(mk_records()[0])
        t.push(p, os.path.basename(p))
        work = tmp_path / "work"
        os.makedirs(work)
        out = str(work / "best.json")
        assert cli.main(["export", "--db", str(work / "db.jsonl"),
                         "--out", out, "--transport", f"mem://{t.bucket}",
                         "--num-shards", "2"]) == 0
        err = capsys.readouterr().err
        assert "not published yet" in err  # shard 1 missing -> loud warning
        assert len(json.load(open(out))) == 1


class TestColdStartBench:
    def test_check_gates(self):
        from benchmarks.cold_start import check

        good = {"cold_start": {
            "unbundled": {"wall_s": 0.2,
                          "pallas_traces": {"matmul": 1, "flash": 1}},
            "bundled": {"wall_s": 0.01,
                        "pallas_traces": {"matmul": 0, "flash": 0}},
            "parity": {"ok": True, "max_abs_diff": 0.0},
        }}
        assert check(good) == []
        import copy

        slow = copy.deepcopy(good)
        slow["cold_start"]["bundled"]["wall_s"] = 0.3
        assert any("strictly faster" in m for m in check(slow))
        traced = copy.deepcopy(good)
        traced["cold_start"]["bundled"]["pallas_traces"]["matmul"] = 1
        assert any("traced Pallas" in m for m in check(traced))
        diverged = copy.deepcopy(good)
        diverged["cold_start"]["parity"] = {"ok": False,
                                            "max_abs_diff": 1.0}
        assert any("diverge" in m for m in check(diverged))
        unmeasured = copy.deepcopy(good)
        unmeasured["cold_start"]["unbundled"]["pallas_traces"] = {
            "matmul": 0, "flash": 0}
        assert any("not measuring" in m for m in check(unmeasured))

    @pytest.mark.slow
    def test_full_benchmark_passes_its_own_check(self, tmp_path):
        from benchmarks.cold_start import check, run_benchmark

        result = run_benchmark(iters=1, ct_configs=4,
                               workdir=str(tmp_path))
        assert check(result) == []
        assert result["cold_start"]["speedup"] > 1.0
