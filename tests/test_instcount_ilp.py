"""Algorithm 1/3 (joint parsing) and the ILP scheduler."""
import pytest

from repro.core import (
    MatmulSpace,
    analyze_ilp,
    count_instructions,
    lower_program,
    match_loops,
)
from repro.core.instcount import identify_loop_spans
from repro.core.tir import Access, Compute, LinExpr, Loop, Program, TensorDecl
from repro.core.visa import VInstr, VisaProgram
from repro.hw import get_target

TPU = get_target("tpu_v5e")
CPU = get_target("cpu_avx2")


def small_matmul(target_kind="tpu", M=256, N=256, K=256, bm=128, bn=128, bk=128):
    space = MatmulSpace(M, N, K, 4, target_kind=target_kind)
    cfg = dict(space.default_config())
    cfg.update({k: v for k, v in dict(bm=bm, bn=bn, bk=bk).items()
                if k in cfg})
    return space, cfg, *space.instantiate(cfg)


class TestLoopIdentification:
    def test_backward_jump_detection(self):
        """Loops are recovered purely from backward jumps + register maps."""
        _, _, prog, _ = small_matmul("tpu")
        visa = lower_program(prog, TPU)
        spans = identify_loop_spans(visa)
        # tpu matmul: gm, gn serial + gk block = 3 recoverable loops
        assert len(spans) == 3
        trips = sorted(s.trips for s in spans)
        assert trips == [2, 2, 2]  # 256/128 each

    def test_algorithm3_register_trip_recovery(self):
        """Trips come from (init, update, bound) register recovery, not
        from any annotation: a hand-built stream with init=2, update=3,
        bound=11 must give ceil((11-2)/3) = 3 trips."""
        visa = VisaProgram([
            VInstr("scalar.addr", "r1", (), {"init": 2}),
            VInstr("label", "LBB1"),
            VInstr("vpu.fma", "v1", ("a", "b")),
            VInstr("scalar.loop", "r1", ("r1",), {"update": 3}),
            VInstr("scalar.jump", None, ("r1",),
                   {"target": "LBB1", "bound": 11}),
        ])
        spans = identify_loop_spans(visa)
        assert len(spans) == 1 and spans[0].trips == 3

    def test_forward_jump_is_not_a_loop(self):
        visa = VisaProgram([
            VInstr("scalar.jump", None, ("r1",), {"target": "LBB9", "bound": 4}),
            VInstr("label", "LBB9"),
            VInstr("vpu.fma", "v1", ("a", "b")),
        ])
        assert identify_loop_spans(visa) == []

    def test_match_skips_collapsed_loops(self):
        """Vectorized/tensorized TIR loops have no VISA block; Alg. 1's scan
        must still match the surviving loops in order."""
        _, _, prog, _ = small_matmul("tpu")
        visa = lower_program(prog, TPU)
        matched, spans = match_loops(prog, visa)
        assert len(matched) == len(spans) == 3
        assert [lp.var for lp, _ in matched] == ["gm", "gn", "gk"]


class TestDynamicCounts:
    def test_mxu_count_equals_tile_count(self):
        _, _, prog, _ = small_matmul("tpu", 512, 512, 512, 128, 128, 128)
        visa = lower_program(prog, TPU)
        rep = count_instructions(prog, visa)
        # (512/128)^3 grid x 1 mxu op per 128^3 nest
        assert rep.counts["mxu.matmul"] == 64

    def test_dma_bytes_match_tiling(self):
        _, _, prog, _ = small_matmul("tpu", 256, 256, 256, 128, 128, 256)
        visa = lower_program(prog, TPU)
        rep = count_instructions(prog, visa)
        # per (gm, gn): A 128x256 + B 256x128 in; C 128x128 hoisted out of
        # the gk block loop but read (accumulate) + written once per entry
        per_step = (128 * 256 + 256 * 128) * 4
        c_inout = 2 * 128 * 128 * 4
        assert rep.dma_bytes == pytest.approx(4 * (per_step + c_inout))

    def test_cpu_accumulator_hoisting_reduces_loads(self):
        """ikj order hoists the C accumulator out of k; kij cannot."""
        space = MatmulSpace(64, 64, 64, 4, target_kind="cpu")
        base = space.default_config()
        cfg_ikj = {**base, "order": "ikj", "unroll_i": 1}
        cfg_kij = {**base, "order": "kij", "unroll_i": 1}
        reps = {}
        for name, cfg in (("ikj", cfg_ikj), ("kij", cfg_kij)):
            prog, _ = space.instantiate(cfg)
            reps[name] = count_instructions(prog, lower_program(prog, CPU))
        ld = lambda r: r.counts.get("simd.load", 0) + r.counts.get(  # noqa: E731
            "simd.store", 0)
        assert ld(reps["ikj"]) < ld(reps["kij"])


class TestIlpScheduler:
    def test_raw_chain_is_serial(self):
        """A chain of dependent FMAs costs latency x n (no ILP)."""
        n = 8
        instrs = [VInstr("vpu.fma", "v0", ("a", "b"))]
        for i in range(1, n):
            instrs.append(VInstr("vpu.fma", f"v{i}", (f"v{i-1}", "b")))
        visa = VisaProgram(instrs)
        rep = analyze_ilp(visa, TPU)
        lat = TPU.latency("vpu.fma")
        assert rep.total_cycles >= lat * n

    def test_independent_ops_pipeline(self):
        """Independent FMAs issue back-to-back: far below latency x n."""
        n = 32
        instrs = [VInstr("vpu.fma", f"v{i}", ("a", "b")) for i in range(n)]
        rep = analyze_ilp(VisaProgram(instrs), TPU)
        lat = TPU.latency("vpu.fma")
        assert rep.total_cycles < lat * n / 2
        # bounded below by unit throughput (2-wide vpu)
        assert rep.total_cycles >= n / 2

    def test_war_hazard_orders_writes(self):
        instrs = [
            VInstr("vpu.fma", "v1", ("a", "b")),
            VInstr("vpu.add", "v2", ("v1", "b")),  # reads v1
            VInstr("vpu.mul", "v1", ("a", "a")),  # WAR on v1
        ]
        rep = analyze_ilp(VisaProgram(instrs), TPU)
        assert rep.total_cycles >= TPU.latency("vpu.fma") + 1

    def test_double_buffer_hides_dma(self):
        """With double buffering the same program's makespan shrinks."""
        _, _, prog, _ = small_matmul("tpu", 512, 512, 512, 128, 128, 128)
        visa = lower_program(prog, TPU)
        sync = analyze_ilp(visa, TPU, double_buffer=False)
        db = analyze_ilp(visa, TPU, double_buffer=True)
        assert db.total_cycles <= sync.total_cycles
        assert db.hidden_dma_frac > 0
