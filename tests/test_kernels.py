"""Pallas kernel allclose sweeps against the ref.py oracles (interpret mode
executes the kernel body on CPU)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.matmul import matmul_pallas

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: 2e-4, jnp.bfloat16: 2e-1}


class TestMatmulKernel:
    @pytest.mark.parametrize("m,n,k,bm,bn,bk", [
        (128, 128, 128, 64, 64, 64),
        (256, 128, 512, 128, 128, 128),
        (64, 256, 128, 64, 128, 128),   # blocks clamp to shape
        (384, 256, 256, 128, 256, 128),  # non-pow2 M
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_against_oracle(self, m, n, k, bm, bn, bk, dtype):
        x, y = _rand((m, k), dtype), _rand((k, n), dtype)
        got = matmul_pallas(x, y, bm=bm, bn=bn, bk=bk, interpret=True)
        want = ref.matmul(x, y)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=TOL[dtype] * np.sqrt(k), rtol=TOL[dtype],
        )

    def test_rejects_indivisible(self):
        x, y = _rand((100, 128), jnp.float32), _rand((128, 128), jnp.float32)
        with pytest.raises(AssertionError):
            matmul_pallas(x, y, bm=64, bn=64, bk=64, interpret=True)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("b,hq,hkv,s,d,bq,bk", [
        (1, 2, 2, 128, 64, 64, 64),     # MHA
        (2, 4, 2, 256, 64, 128, 64),    # GQA 2:1
        (1, 8, 1, 128, 32, 64, 128),    # MQA
        (2, 4, 4, 512, 128, 256, 128),
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_against_oracle(self, b, hq, hkv, s, d, bq, bk, causal):
        q = _rand((b, hq, s, d), jnp.float32)
        k = _rand((b, hkv, s, d), jnp.float32)
        v = _rand((b, hkv, s, d), jnp.float32)
        got = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                                     block_k=bk, interpret=True)
        want = ref.attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-4)

    def test_bf16(self):
        q = _rand((1, 4, 128, 64), jnp.bfloat16)
        k = _rand((1, 2, 128, 64), jnp.bfloat16)
        v = _rand((1, 2, 128, 64), jnp.bfloat16)
        got = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                                     block_k=64, interpret=True)
        want = ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=5e-2, rtol=5e-2)


class TestChunkedAttention:
    """The jnp flash mirror used on non-TPU backends must match the oracle."""

    @pytest.mark.parametrize("s,chunk", [(256, 64), (512, 128), (128, 128)])
    def test_matches_oracle(self, s, chunk):
        from repro.models.attention import chunked_attention

        q = _rand((2, 4, s, 32), jnp.float32)
        k = _rand((2, 2, s, 32), jnp.float32)
        v = _rand((2, 2, s, 32), jnp.float32)
        got = chunked_attention(q, k, v, causal=True, chunk=chunk)
        want = ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-4)
