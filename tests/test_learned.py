"""Learned ranker: featurization, ridge fit, artifact integrity, the
hybrid serve path in core.tuner, the LearnedManager ensure-on-change
lifecycle, and this PR's satellite bugfixes (warm-hit default_score NaN,
sample_space silent cap, calibrated-version lookup skew, and the
$REPRO_TUNA_LEARNED degrade paths)."""
import json
import math
import os

import numpy as np
import pytest

from repro.core import cost_model, tuner
from repro.core.cost_model import COST_MODEL_VERSION
from repro.core.learned import (
    FEATURE_NAMES,
    LearnedRanker,
    featurize,
    fit_ranker,
    load_ranker,
    measured_version,
    save_ranker,
    space_from_signature,
    spearman,
)
from repro.core.spaces import MatmulSpace, Space
from repro.hw import get_target
from repro.tuna.cache import StaleSnapshotError, StaleSnapshotWarning
from repro.tuna.db import ScheduleDatabase, ScheduleRecord
from repro.tuna.learned import (
    LearnedManager,
    build_dataset,
    iter_log_records,
    train_from_store,
    training_rows,
    training_sha1,
)

CPU = get_target("cpu_avx2")
TPU = get_target("tpu_v5e")


def _space() -> MatmulSpace:
    return MatmulSpace(64, 64, 64, 4, target_kind="cpu")


def _fit_synthetic(space=None, target=CPU, n=80, seed=0):
    """A ranker fitted on scores that are exactly log-linear in the
    feature vector — the fit must recover the ordering."""
    space = space or _space()
    cfgs = list(space.enumerate(space.size()))[:n]
    X = np.stack([featurize(space, target, c) for c in cfgs])
    rng = np.random.default_rng(seed)
    w = rng.standard_normal(X.shape[1])
    y = np.exp((X - X.mean(0)) / (X.std(0) + 1e-9) @ w * 0.1)
    model = fit_ranker(X, y, ["cm1-meas"] * len(y))
    return model, space, cfgs, X, y


class TestFeaturesAndFit:
    def test_featurize_finite_and_deterministic(self):
        space = _space()
        cfg = space.default_config()
        v1 = featurize(space, CPU, cfg)
        v2 = featurize(space, CPU, cfg)
        assert v1.shape == (len(FEATURE_NAMES),)
        assert np.all(np.isfinite(v1)) and np.array_equal(v1, v2)
        # config knobs actually move the vector
        other = dict(cfg)
        other["bm"] = [b for b in space.knobs["bm"] if b != cfg["bm"]][0]
        assert not np.array_equal(v1, featurize(space, CPU, other))

    def test_fit_recovers_synthetic_ranking(self):
        model, space, cfgs, X, y = _fit_synthetic()
        rho = spearman(model.predict(X), np.log(y))
        assert rho > 0.95

    def test_per_lineage_standardisation_isolates_scales(self):
        """Two lineages with wildly different score scales but the same
        ordering must train as cleanly as one lineage would."""
        space = _space()
        cfgs = list(space.enumerate(space.size()))[:60]
        X = np.stack([featurize(space, CPU, c) for c in cfgs])
        w = np.linspace(-1, 1, X.shape[1])
        base = np.exp((X - X.mean(0)) / (X.std(0) + 1e-9) @ w * 0.1)
        X2 = np.concatenate([X, X])
        y2 = np.concatenate([base, base * 1e6])  # same order, huge offset
        lins = ["cm1"] * len(base) + ["cm1-meas"] * len(base)
        model = fit_ranker(X2, y2, lins)
        rho = spearman(model.predict(X), np.log(base))
        assert rho > 0.95
        assert model.lineages == {"cm1": 60, "cm1-meas": 60}

    def test_rerank_orders_head_only(self):
        model, space, cfgs, X, y = _fit_synthetic()
        static = [(c, float(s)) for c, s in zip(cfgs, y)]
        static.sort(key=lambda cs: cs[1])
        out = model.rerank(space, CPU, static, top=10)
        assert len(out) == len(static)
        assert out[10:] == static[10:]          # tail untouched
        assert sorted(map(str, out[:10])) == sorted(map(str, static[:10]))
        preds = model.predict(
            np.stack([featurize(space, CPU, c) for c, _ in out[:10]]))
        assert list(preds) == sorted(preds)     # head in learned order

    def test_space_from_signature_roundtrip(self):
        from repro.configs.tuna_ops import OPERATORS

        for name, make in OPERATORS.items():
            space = make("cpu")
            back = space_from_signature(space.signature(), CPU)
            assert back is not None, name
            assert back.signature() == space.signature()
            assert back.knobs == space.knobs
        assert space_from_signature("cell[L=4]", CPU) is None


class TestArtifact:
    def test_save_load_roundtrip_and_version_tag(self, tmp_path):
        import re

        model, space, cfgs, X, y = _fit_synthetic()
        path = str(tmp_path / "m.json")
        save_ranker(model, path)
        back = load_ranker(path)
        assert re.fullmatch(rf"{COST_MODEL_VERSION}\+lr[0-9a-f]{{8}}",
                            back.version)
        assert back.version == model.version
        assert back.hybrid_version("cm1-cal-abc12345") == \
            f"cm1-cal-abc12345+lr{model.fingerprint()[:8]}"
        assert np.allclose(back.predict(X), model.predict(X))
        assert back.lineages == model.lineages

    def test_corrupt_payload_rejected(self, tmp_path):
        model, *_ = _fit_synthetic()
        path = str(tmp_path / "m.json")
        save_ranker(model, path)
        obj = json.load(open(path))
        obj["model"]["weights"][0] += 1.0  # sha1 no longer matches
        json.dump(obj, open(path, "w"))
        with pytest.raises(ValueError, match="digest mismatch"):
            load_ranker(path)

    def test_fingerprint_tamper_rejected(self, tmp_path):
        """A mis-assembled artifact whose payload digest checks out but
        whose version tag names different parameters must refuse to load:
        the fingerprint is re-derived from the parameters at load."""
        import hashlib

        model, *_ = _fit_synthetic()
        path = str(tmp_path / "m.json")
        save_ranker(model, path)
        obj = json.load(open(path))
        obj["model"]["weights"][0] += 1.0
        blob = json.dumps(obj["model"], sort_keys=True, default=float)
        obj["sha1"] = hashlib.sha1(blob.encode()).hexdigest()  # "fix" sha
        json.dump(obj, open(path, "w"))
        with pytest.raises(ValueError, match="fingerprint mismatch"):
            load_ranker(path)

    def test_stale_cost_model_version_rejected(self, tmp_path):
        model, *_ = _fit_synthetic()
        model.cost_model_version = "cm0"
        path = str(tmp_path / "m.json")
        save_ranker(model, path)  # self-consistent artifact, wrong cm
        with pytest.raises(StaleSnapshotError, match="cm0"):
            load_ranker(path)
        with pytest.raises(StaleSnapshotError):
            tuner.set_default_learned(path)  # explicit install: loud

    def test_env_learned_missing_resolves_off(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNA_LEARNED",
                           str(tmp_path / "never_trained.json"))
        monkeypatch.setattr(tuner, "_DEFAULT_LEARNED", tuner._UNSET)
        assert tuner.get_default_learned() is None

    def test_env_learned_stale_warns_off_and_clears_memos(self, tmp_path,
                                                          monkeypatch):
        """Satellite: $REPRO_TUNA_LEARNED degrades to OFF with a warning
        AND clears the block-spec memos — mirroring the cache/bundle
        degrade paths, so shapes memoised under an earlier model never
        outlive its rejection."""
        model, *_ = _fit_synthetic()
        model.cost_model_version = "cm0"
        stale = str(tmp_path / "stale.json")
        save_ranker(model, stale)
        cleared = []
        tuner.register_memo_clearer(lambda: cleared.append(1))
        try:
            monkeypatch.setenv("REPRO_TUNA_LEARNED", stale)
            monkeypatch.setattr(tuner, "_DEFAULT_LEARNED", tuner._UNSET)
            with pytest.warns(StaleSnapshotWarning,
                              match="REPRO_TUNA_LEARNED disabled"):
                assert tuner.get_default_learned() is None
            assert cleared
        finally:
            tuner._MEMO_CLEARERS.pop()


class TestHybridServe:
    def test_miss_writes_hybrid_version_then_warm_hits(self, tmp_path,
                                                       monkeypatch):
        model, space, *_ = _fit_synthetic()
        path = str(tmp_path / "db.jsonl")
        tuner.set_default_learned(model)
        cfg, score = tuner.best_schedule(space, CPU, db=path)
        db = ScheduleDatabase(path)
        hv = model.hybrid_version(COST_MODEL_VERSION)
        rec = db.best(space.signature(), CPU.name, version=hv)
        assert rec is not None and rec.meta["strategy"] == "hybrid"
        assert rec.config == cfg
        # no plain-cm1 record was written for the hybrid search
        assert db.best(space.signature(), CPU.name) is None

        def boom(*a, **kw):
            raise AssertionError("searched despite hybrid warm record")

        monkeypatch.setattr(cost_model, "evaluate", boom)
        again = tuner.best_schedule(_space(), CPU, db=path)
        assert again == (cfg, score)

    def test_hybrid_falls_back_to_plain_static_records(self, tmp_path,
                                                       monkeypatch):
        """Installing a learned model must not orphan existing cm1
        records: the hybrid lineage is consulted first, plain cm1 second."""
        space = _space()
        path = str(tmp_path / "db.jsonl")
        ranked = tuner.rank_space(space, CPU, limit=space.size(), db=path)
        model, *_ = _fit_synthetic()
        tuner.set_default_learned(model)

        def boom(*a, **kw):
            raise AssertionError("searched despite plain cm1 warm record")

        monkeypatch.setattr(cost_model, "evaluate", boom)
        cfg, score = tuner.best_schedule(_space(), CPU, db=path)
        assert (cfg, score) == ranked[0]

    def test_calibrated_version_warm_hit_regression(self, tmp_path,
                                                    monkeypatch):
        """Satellite (lookup-tier skew): a calibrated-coefficient write
        must be a calibrated warm hit — before the version passthrough,
        best_schedule always probed plain cm1 and re-searched."""
        space = _space()
        path = str(tmp_path / "db.jsonl")
        coeffs = dict(cost_model.coefficients(CPU), ilp_cycles=2.0)
        ranked = tuner.rank_space(space, CPU, limit=space.size(),
                                  coeffs=coeffs, db=path)
        version = tuner.record_version(coeffs)
        assert version.startswith(f"{COST_MODEL_VERSION}-cal-")

        def boom(*a, **kw):
            raise AssertionError("searched despite calibrated warm record")

        monkeypatch.setattr(cost_model, "evaluate", boom)
        # derived from coeffs...
        assert tuner.best_schedule(_space(), CPU, coeffs=coeffs,
                                   db=path) == ranked[0]
        # ...and pinned explicitly
        assert tuner.best_schedule(_space(), CPU, version=version,
                                   db=path) == ranked[0]

    def test_warm_hit_missing_default_score_flagged(self, tmp_path,
                                                    monkeypatch):
        """Satellite (NaN poisoning): rank_space with the centre config
        outside the limit stores no default_score; the warm hit must say
        so explicitly instead of handing out a bare NaN that later
        serializes as invalid JSON."""
        space = MatmulSpace(1024, 1024, 1024, 2, target_kind="tpu")
        path = str(tmp_path / "db.jsonl")
        tuner.rank_space(space, TPU, limit=1, db=path)  # centre excluded
        rec = ScheduleDatabase(path).best(space.signature(), TPU.name)
        assert "default_score" not in rec.meta

        res = tuner.tune(MatmulSpace(1024, 1024, 1024, 2, "tpu"), TPU,
                         db=path)
        assert res.from_db and res.default_score_missing
        assert math.isnan(res.default_score)

        from benchmarks.bench_json import write_bench

        out = str(tmp_path / "BENCH_x.json")
        clean = write_bench({"default_score": res.default_score,
                             "speedup": [1.0, res.default_score],
                             "nested": {"score": res.score}}, out)
        back = json.load(open(out))  # strictly valid JSON round-trip
        assert back == clean
        assert back["default_score"] is None
        assert back["default_score_missing"] is True
        assert back["speedup"] == [1.0, None]
        assert back["nested"]["score"] == pytest.approx(res.score)


class TestSampleSpaceLimit:
    class BigSpace(Space):
        name = "bigspace"

        def __init__(self):
            super().__init__()
            self.knobs = {"a": list(range(16)), "b": list(range(16)),
                          "c": list(range(16)), "d": [0, 1]}

    def test_full_space_by_default(self):
        """Satellite (silent cap): the candidate pool used to be silently
        truncated at 4096 regardless of space size."""
        from benchmarks.topk_ratio import sample_space

        space = self.BigSpace()
        assert space.size() == 8192
        pool = sample_space(space, space.size())
        assert len(pool) == 8192  # old code: 4096

    def test_explicit_limit_is_loud(self, capsys):
        from benchmarks.topk_ratio import sample_space

        space = self.BigSpace()
        got = sample_space(space, 10, seed=3, limit=100)
        assert len(got) == 10
        assert "truncated to 100 of 8192" in capsys.readouterr().err
        # an un-truncating limit stays quiet
        sample_space(self.BigSpace(), 10, seed=3, limit=10_000)
        assert "truncated" not in capsys.readouterr().err


def _seed_store(path, spaces=(("cpu", 64), ("cpu", 128)), per_space=24,
                version=None):
    """A store whose log carries measured-lineage samples (score = static
    cm1 score times a deterministic perturbation) for a couple of spaces."""
    db = ScheduleDatabase(path)
    version = version or measured_version()
    rng = np.random.default_rng(0)
    for kind, n in spaces:
        space = MatmulSpace(n, n, n, 4, target_kind=kind)
        target = CPU if kind == "cpu" else TPU
        cfgs = list(space.enumerate(space.size()))[:per_space]
        for cfg in cfgs:
            s = tuner._score_config(space, target, cfg)
            db.add(ScheduleRecord(
                op=space.signature(), target=target.name, config=cfg,
                score=float(s * rng.uniform(0.8, 1.25)), evaluations=1,
                meta={"strategy": "measured_sample"}, version=version))
    return db


class TestTrainingAndLifecycle:
    def test_log_not_index_is_the_training_set(self, tmp_path):
        path = str(tmp_path / "db.jsonl")
        db = _seed_store(path)
        # the index keeps one winner per (op, target, version)...
        assert len(db) == 2
        rows = training_rows(iter_log_records(path))
        assert len(rows) == 48  # ...but the log keeps every sample

    def test_training_rows_exclude_hybrid_and_foreign(self):
        mk = lambda v: ScheduleRecord(op="matmul[K=64,M=64,N=64,"
                                      "dtype_bytes=4]", target="cpu_avx2",
                                      config={}, score=1.0, version=v)
        rows = training_rows([mk("cm1"), mk("cm1-cal-deadbeef"),
                              mk("cm1-meas"), mk("cm1-cal-ab+lr12345678"),
                              mk("cm1+lr12345678"), mk("cm0")])
        assert [r.version for r in rows] == ["cm1", "cm1-cal-deadbeef",
                                             "cm1-meas"]

    def test_training_sha1_ignores_order_and_bookkeeping(self):
        a = ScheduleRecord(op="x[]", target="t", config={"bm": 4}, score=1.0,
                           meta={"tuned_at": 1.0, "provenance": "s0"})
        b = ScheduleRecord(op="y[]", target="t", config={"bm": 8}, score=2.0)
        a2 = ScheduleRecord(op="x[]", target="t", config={"bm": 4}, score=1.0,
                            meta={"tuned_at": 999.0})
        assert training_sha1([a, b]) == training_sha1([b, a2])
        assert training_sha1([a]) != training_sha1([b])

    def test_train_from_store_and_eval_quality(self, tmp_path):
        path = str(tmp_path / "db.jsonl")
        _seed_store(path, per_space=32)
        model, tsha, samples, skipped = train_from_store(path)
        assert samples == 64 and skipped == 0
        assert model.lineages == {measured_version(): 64}
        # in-sample ordering of a noisy-but-monotone target is learnable
        rows = training_rows(iter_log_records(path))
        X, y, groups, _ = build_dataset(rows)
        rhos = []
        for g in set(groups):
            m = np.asarray([gi == g for gi in groups])
            rhos.append(spearman(model.predict(X[m]), np.log(y[m])))
        assert sum(rhos) / len(rhos) > 0.5

    def test_manager_ensure_on_change_and_publish(self, tmp_path):
        from repro.tuna.transport import resolve_transport

        path = str(tmp_path / "db.jsonl")
        db = _seed_store(path)
        mgr = LearnedManager(path, str(tmp_path / "learned"))
        info = mgr.ensure()
        assert info.retrained and info.repointed
        assert os.path.exists(info.path) and os.path.exists(info.latest)
        # verified load through the pointer
        assert load_ranker(info.latest).version == info.version

        again = mgr.ensure()  # content unchanged → no-op
        assert not again.retrained and not again.repointed
        assert again.train_sha1 == info.train_sha1

        # new training content → retrain
        space = MatmulSpace(32, 32, 32, 4, target_kind="cpu")
        cfg = space.default_config()
        db.add(ScheduleRecord(
            op=space.signature(), target=CPU.name, config=cfg,
            score=float(tuner._score_config(space, CPU, cfg)),
            version=measured_version()))
        third = mgr.ensure()
        assert third.retrained and third.train_sha1 != info.train_sha1

        t = resolve_transport(f"mem://learned-{os.getpid()}")
        manifests = mgr.publish(t)
        assert [m.name for m in manifests] == \
            [third.name, "learned.latest.json"]

    def test_manager_refuses_empty_store(self, tmp_path):
        path = str(tmp_path / "db.jsonl")
        ScheduleDatabase(path).add(ScheduleRecord(
            op="x[]", target="cpu_avx2", config={}, score=1.0,
            version="cm0"))  # foreign lineage only
        with pytest.raises(ValueError, match="usable training sample"):
            LearnedManager(path, str(tmp_path / "learned")).ensure()

    def test_controller_retrains_and_publishes_on_change(self, tmp_path):
        from repro.tuna.controller import ControllerConfig, FleetController

        path = str(tmp_path / "db.jsonl")
        db = _seed_store(path)
        bucket = f"mem://ctl-learned-{os.getpid()}"
        cfg = ControllerConfig(
            db=path, ops=[], targets=[], num_shards=1,
            learned_dir=str(tmp_path / "learned"), publish=bucket,
            quiet=True)
        ctl = FleetController(cfg, jobs=[])
        ctl.ensure_learned()
        assert ctl.metrics.get("learned_retrains_total") == 1
        assert ctl.metrics.get("learned_publishes_total") == 1
        ctl.ensure_learned()  # no change → no retrain, no republish
        assert ctl.metrics.get("learned_retrains_total") == 1
        assert ctl.metrics.get("learned_publishes_total") == 1
        space = MatmulSpace(32, 32, 32, 4, target_kind="cpu")
        cfg2 = space.default_config()
        db.add(ScheduleRecord(
            op=space.signature(), target=CPU.name, config=cfg2,
            score=float(tuner._score_config(space, CPU, cfg2)),
            version=measured_version()))
        ctl.ensure_learned()
        assert ctl.metrics.get("learned_retrains_total") == 2
        assert ctl.metrics.get("learned_publishes_total") == 2

    def test_cli_train_eval_smoke(self, tmp_path, capsys):
        from repro.tuna import cli

        path = str(tmp_path / "db.jsonl")
        _seed_store(path, per_space=32)
        out_dir = str(tmp_path / "learned")
        assert cli.main(["train", "--db", path, "--dir", out_dir]) == 0
        assert "retrained" in capsys.readouterr().out
        latest = os.path.join(out_dir, "learned.latest.json")
        assert os.path.exists(latest)
        assert cli.main(["train", "--db", path, "--dir", out_dir]) == 0
        assert "up to date" in capsys.readouterr().out
        assert cli.main(["eval", "--db", path, "--model", latest,
                         "--check", "--min-spearman", "0.3"]) == 0
        assert "CHECK OK" in capsys.readouterr().out
        # an empty store is a clean CLI error, not a traceback
        empty = str(tmp_path / "empty.jsonl")
        open(empty, "w").close()
        assert cli.main(["train", "--db", empty, "--dir", out_dir]) == 1
