"""Algorithm 2 faithfulness: the paper's own 2MM worked example, plus
structural properties of the footprint/movement model."""
import pytest

from repro.core.tir import Access, Compute, LinExpr, Loop, Program, TensorDecl
from repro.core.locality import analyze_locality


def two_mm(Ni, Nj, Nk, Nl, Ti, Tj):
    """Listing 1: fused+tiled 2MM. E[i,l] = (A@B)[i,j] @ D[j,l]."""
    A = TensorDecl("A", (Ni, Nk), 4)
    B = TensorDecl("B", (Nk, Nj), 4)
    C = TensorDecl("C", (Ni, Nj), 4)
    D = TensorDecl("D", (Nj, Nl), 4)
    E = TensorDecl("E", (Ni, Nl), 4)
    ix = LinExpr.of(("it", Ti), ("i1", 1))
    jx = LinExpr.of(("jt", Tj), ("j1", 1))
    ix2 = LinExpr.of(("it", Ti), ("i2", 1))
    jx2 = LinExpr.of(("jt", Tj), ("j2", 1))

    mm1 = Compute(
        "fma",
        output=Access("C", (ix, jx), is_store=True),
        inputs=(Access("A", (ix, LinExpr.var("k"))),
                Access("B", (LinExpr.var("k"), jx))),
    )
    mm2 = Compute(
        "fma",
        output=Access("E", (ix2, LinExpr.var("l")), is_store=True),
        inputs=(Access("C", (ix2, jx2)),
                Access("D", (jx2, LinExpr.var("l")))),
    )
    first = Loop("k", Nk, (Loop("i1", Ti, (Loop("j1", Tj, (mm1,)),)),))
    second = Loop("l", Nl, (Loop("i2", Ti, (Loop("j2", Tj, (mm2,)),)),))
    nest = Loop("it", Ni // Ti, (Loop("jt", Nj // Tj, (first, second)),))
    return Program((A, B, C, D, E), (nest,), name="2mm")


class TestPaper2MM:
    """S chosen so one jt-iteration footprint fits but one it-iteration does
    not — the paper's capacity assumption."""

    Ni = Nj = Nk = Nl = 128
    Ti = Tj = 16

    def paper_numbers(self):
        Ni, Nj, Nk, Nl, Ti, Tj = (self.Ni, self.Nj, self.Nk, self.Nl,
                                  self.Ti, self.Tj)
        fp_jt_iter = Ti * Tj + Ti * Nl + Tj * Nl + Tj * Nk + Ti * Nk
        mov_jt = Ti * Nj + Ti * Nl + Nj * Nl + Nj * Nk + Ti * Nk
        mov_it = mov_jt * (Ni // Ti)
        return fp_jt_iter, mov_jt, mov_it

    def test_movement_matches_paper_formula(self):
        fp_jt_iter, mov_jt, mov_it = self.paper_numbers()
        cache = 64 * 1024  # 16384 elements: > fp_jt_iter, < fp_it_iter
        assert fp_jt_iter * 4 <= cache < mov_jt * 4
        prog = two_mm(self.Ni, self.Nj, self.Nk, self.Nl, self.Ti, self.Tj)
        rep = analyze_locality(prog, cache)
        assert rep.movement_bytes == pytest.approx(mov_it * 4)

    def test_everything_fits_means_movement_equals_footprint(self):
        prog = two_mm(self.Ni, self.Nj, self.Nk, self.Nl, self.Ti, self.Tj)
        rep = analyze_locality(prog, cache_bytes=10 * 2**20)
        assert rep.movement_bytes == pytest.approx(rep.footprint_bytes)
        # footprint = all five matrices
        assert rep.footprint_bytes == pytest.approx(5 * 128 * 128 * 4)

    def test_movement_monotone_in_cache(self):
        prog = two_mm(self.Ni, self.Nj, self.Nk, self.Nl, self.Ti, self.Tj)
        movs = [
            analyze_locality(prog, c).movement_bytes
            for c in (2**12, 2**14, 2**16, 2**18, 2**22)
        ]
        assert all(a >= b for a, b in zip(movs, movs[1:]))
        assert movs[-1] >= analyze_locality(prog, 2**22).footprint_bytes - 1e-6

    def test_larger_tiles_less_movement_under_same_cache(self):
        cache = 64 * 1024
        small = analyze_locality(two_mm(128, 128, 128, 128, 8, 8), cache)
        # Ti=Tj=16 keeps the jt working set within cache; Ti=8 pays more
        # it-loop trips -> more movement
        big = analyze_locality(two_mm(128, 128, 128, 128, 16, 16), cache)
        assert small.movement_bytes >= big.movement_bytes
