"""Property-based tests: ``ScheduleDatabase`` merge is a semilattice join.

Fleet sync only converges (any host, any merge order, any retry count →
the same store) if absorbing records is governed by a *total* order:
commutative, associative, idempotent. Hypothesis drives merge over record
sets drawn from a deliberately tiny value pool so same-key conflicts —
conflicting versions, conflicting scores, exact score ties with different
configs — occur constantly.
"""
import os
import tempfile

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.tuna.db import ScheduleDatabase, ScheduleRecord, record_beats  # noqa: E402

SETTINGS = settings(max_examples=25, deadline=None)

records = st.builds(
    ScheduleRecord,
    op=st.sampled_from(["a[]", "b[]", "c[]"]),
    target=st.sampled_from(["t0", "t1"]),
    version=st.sampled_from(["cm1", "cm1-cal-x"]),
    config=st.fixed_dictionaries({"bm": st.sampled_from([64, 128, 256])}),
    score=st.sampled_from([1.0, 2.0, 3.0]),  # small pool → frequent ties
    evaluations=st.integers(min_value=0, max_value=3),
    meta=st.fixed_dictionaries(
        {"strategy": st.sampled_from(["es", "exhaustive"])}),
)
record_lists = st.lists(records, max_size=8)


def _store(d: str, name: str, recs) -> str:
    db = ScheduleDatabase(os.path.join(d, name))
    open(db.path, "a").close()  # an empty shard store is still a store
    for r in recs:
        db.add(r)  # full history lands in the log, like a real shard store
    return db.path


def _merge(d: str, name: str, paths) -> ScheduleDatabase:
    db = ScheduleDatabase(os.path.join(d, name))
    open(db.path, "a").close()  # merged-but-empty stores are sources too
    db.merge_all(paths, provenance=False)
    return db


def _bestset(db: ScheduleDatabase):
    return frozenset(r.to_json() for r in db.records())


class TestMergeAlgebra:
    @SETTINGS
    @given(record_lists, record_lists)
    def test_commutative(self, xs, ys):
        with tempfile.TemporaryDirectory() as d:
            pa, pb = _store(d, "a", xs), _store(d, "b", ys)
            ab = _merge(d, "ab", [pa, pb])
            ba = _merge(d, "ba", [pb, pa])
            assert _bestset(ab) == _bestset(ba)

    @SETTINGS
    @given(record_lists, record_lists, record_lists)
    def test_associative(self, xs, ys, zs):
        with tempfile.TemporaryDirectory() as d:
            pa, pb, pc = (_store(d, "a", xs), _store(d, "b", ys),
                          _store(d, "c", zs))
            left = _merge(d, "l", [_merge(d, "ab", [pa, pb]).path, pc])
            right = _merge(d, "r", [pa, _merge(d, "bc", [pb, pc]).path])
            assert _bestset(left) == _bestset(right)

    @SETTINGS
    @given(record_lists)
    def test_idempotent(self, xs):
        with tempfile.TemporaryDirectory() as d:
            pa = _store(d, "a", xs)
            once = _merge(d, "m1", [pa])
            twice = _merge(d, "m2", [pa, pa])
            assert _bestset(once) == _bestset(twice)
            # re-merging into an existing store absorbs nothing and leaves
            # the log byte-identical
            blob = open(once.path, "rb").read()
            assert once.merge(pa, provenance=False) == 0
            assert open(once.path, "rb").read() == blob

    @SETTINGS
    @given(records, records)
    def test_record_order_is_total_and_antisymmetric(self, r1, r2):
        if r1.key != r2.key:
            return
        if r1.to_json() == r2.to_json():
            assert not record_beats(r1, r2) and not record_beats(r2, r1)
        else:
            assert record_beats(r1, r2) != record_beats(r2, r1)
