"""Per-arch smoke tests (reduced configs) + decode/prefill consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models.model import Model

RNG = np.random.default_rng(0)
B, S = 2, 16


def make_batch(cfg, b=B, s=S):
    batch = {
        "tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.frontend == "audio":
        batch["frames"] = jnp.asarray(
            0.1 * RNG.standard_normal((b, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(
            0.1 * RNG.standard_normal((b, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.float32)
    return batch


# big hybrid archs (deep scans / many experts) dominate suite wall time;
# their cells run as slow so tier-1 stays well under its 120 s budget
HEAVY_ARCHS = {"jamba_v01_52b", "xlstm_13b"}


def _arch_param(arch, heavy=HEAVY_ARCHS):
    marks = [pytest.mark.slow] if arch in heavy else []
    return pytest.param(arch, marks=marks)


@pytest.mark.parametrize("arch", [_arch_param(a) for a in ARCH_IDS])
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = get_config(arch).reduced()
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        batch = make_batch(cfg)
        loss, metrics = jax.jit(model.loss)(params, batch)
        assert jnp.isfinite(loss), arch
        assert loss.shape == ()
        # one SGD-ish step moves the loss (gradients flow end to end)
        grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
        gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                    for g in jax.tree.leaves(grads))
        assert np.isfinite(gnorm) and gnorm > 0, arch

    def test_logit_shapes(self, arch):
        cfg = get_config(arch).reduced()
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        logits = model.logits(params, make_batch(cfg))
        assert logits.shape == (B, S, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


DECODE_TOL = {
    "jamba_v01_52b": 5e-4, "xlstm_13b": 5e-3,
}


@pytest.mark.parametrize("arch", [
    _arch_param(a, heavy=HEAVY_ARCHS | {"qwen3_moe_235b_a22b"})
    for a in ("yi_6b", "qwen3_moe_235b_a22b", "jamba_v01_52b", "xlstm_13b",
              "whisper_large_v3", "internvl2_1b")
])
class TestDecodeConsistency:
    """Teacher-forced decode (step-by-step with caches) must match the full
    parallel forward — validates KV caches, SSM states and positions."""

    def test_prefill_plus_decode_matches_forward(self, arch):
        cfg = get_config(arch).reduced()
        model = Model(cfg)
        params = model.init(jax.random.key(1))
        batch = make_batch(cfg)
        full = model.logits(params, batch)  # [B,S,V]

        npfx = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
        cap = S + npfx
        prefix = {**batch, "tokens": batch["tokens"][:, : S - 1]}
        cache, pos, _ = model.prefill(params, prefix, cap)
        lg, _ = model.decode_step(params, cache, batch["tokens"][:, S - 1], pos)
        tol = DECODE_TOL.get(arch, 2e-4)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]),
                                   atol=tol * 50, rtol=tol * 10)

    def test_decode_from_scratch_matches_forward(self, arch):
        cfg = get_config(arch).reduced()
        if cfg.encoder_decoder or cfg.frontend == "vision":
            pytest.skip("prefix modalities covered by the prefill test")
        model = Model(cfg)
        params = model.init(jax.random.key(1))
        batch = make_batch(cfg)
        full = model.logits(params, batch)
        cache = model.init_cache(B, S)
        outs = []
        for t in range(S):
            lg, cache = model.decode_step(params, cache, batch["tokens"][:, t],
                                          jnp.asarray(t, jnp.int32))
            outs.append(lg)
        dec = jnp.stack(outs, axis=1)
        tol = DECODE_TOL.get(arch, 2e-4)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                                   atol=tol * 50, rtol=tol * 10)


class TestPatternAssembly:
    def test_jamba_pattern(self):
        cfg = get_config("jamba_v01_52b")
        pat = cfg.pattern()
        assert len(pat) == 8
        assert sum(1 for m, _ in pat if m == "attention") == 1
        assert sum(1 for m, _ in pat if m == "mamba") == 7
        assert sum(1 for _, m in pat if m == "moe") == 4  # every 2nd layer

    def test_xlstm_pattern(self):
        cfg = get_config("xlstm_13b")
        pat = cfg.pattern()
        assert sum(1 for m, _ in pat if m == "slstm") == 1
        assert sum(1 for m, _ in pat if m == "mlstm") == 7
        assert all(mlp == "none" for _, mlp in pat)

    def test_param_count_formula_close_to_eval_shape(self):
        """The analytic 6·N·D bookkeeping must track the real tree size."""
        for arch in ("yi_6b", "qwen3_moe_235b_a22b", "whisper_large_v3"):
            cfg = get_config(arch)
            model = Model(cfg)
            shapes = jax.eval_shape(lambda m=model: m.init(jax.random.key(0)))
            n_real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
            n_formula = cfg.param_count()
            assert abs(n_real - n_formula) / n_real < 0.05, (
                arch, n_real, n_formula)
