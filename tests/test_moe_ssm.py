"""MoE routing correctness vs brute force; Mamba/xLSTM vs step oracles."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod

RNG = np.random.default_rng(3)


class TestMoE:
    def _cfg(self, cf=8.0):
        cfg = get_config("qwen3_moe_235b_a22b").reduced()
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cf)
        )

    def test_matches_bruteforce_when_capacity_ample(self):
        """With no drops, gather-dispatch MoE == explicit per-token loop."""
        cfg = self._cfg(cf=8.0)
        p = moe_mod.init_moe(cfg, jax.random.key(0))
        x = jnp.asarray(RNG.standard_normal((2, 8, cfg.d_model)), jnp.float32)
        y, aux = moe_mod.apply_moe(cfg, p, x)

        idx, gates, _ = moe_mod.route(cfg, p, x)
        want = np.zeros(x.shape, np.float32)
        for b in range(x.shape[0]):
            for t in range(x.shape[1]):
                for s in range(cfg.moe.top_k):
                    e = int(idx[b, t, s])
                    h = x[b, t] @ p["w1"][e]
                    g = x[b, t] @ p["w3"][e]
                    act = jax.nn.silu(h) * g
                    want[b, t] += float(gates[b, t, s]) * np.asarray(
                        act @ p["w2"][e])
        np.testing.assert_allclose(np.asarray(y), want, atol=1e-4, rtol=1e-3)
        assert np.isfinite(float(aux))

    def test_capacity_drops_bounded(self):
        """cf=0.25 must produce smaller-magnitude output (tokens dropped),
        never NaN."""
        cfg_full = self._cfg(cf=8.0)
        cfg_tight = self._cfg(cf=0.25)
        p = moe_mod.init_moe(cfg_full, jax.random.key(0))
        x = jnp.asarray(RNG.standard_normal((2, 16, cfg_full.d_model)),
                        jnp.float32)
        y_full, _ = moe_mod.apply_moe(cfg_full, p, x)
        y_tight, _ = moe_mod.apply_moe(cfg_tight, p, x)
        assert bool(jnp.all(jnp.isfinite(y_tight)))
        assert float(jnp.linalg.norm(y_tight)) <= float(
            jnp.linalg.norm(y_full)) + 1e-5

    def test_aux_loss_balanced_router_is_minimal(self):
        """Uniform routing gives aux ~ 1 (the Switch loss optimum)."""
        cfg = self._cfg()
        p = moe_mod.init_moe(cfg, jax.random.key(0))
        p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform probs
        x = jnp.asarray(RNG.standard_normal((2, 64, cfg.d_model)), jnp.float32)
        _, _, aux = moe_mod.route(cfg, p, x)
        assert float(aux) == pytest.approx(1.0, abs=0.25)


class TestMambaOracle:
    def test_chunked_scan_matches_stepwise(self):
        cfg = get_config("jamba_v01_52b").reduced()
        p = ssm_mod.init_mamba(cfg, jax.random.key(0))
        b, s = 2, 24
        x = jnp.asarray(0.5 * RNG.standard_normal((b, s, cfg.d_model)),
                        jnp.float32)
        y_par, state = ssm_mod.mamba_forward(cfg, p, x, chunk=8,
                                             return_state=True)

        cache = ssm_mod.init_mamba_cache(cfg, b, jnp.float32)
        ys = []
        for t in range(s):
            yt, cache = ssm_mod.mamba_decode(cfg, p, x[:, t: t + 1], cache)
            ys.append(yt)
        y_seq = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                                   atol=2e-4, rtol=2e-3)
        np.testing.assert_allclose(np.asarray(state["h"]),
                                   np.asarray(cache["h"]), atol=2e-4,
                                   rtol=2e-3)

    def test_chunk_size_invariance(self):
        cfg = get_config("jamba_v01_52b").reduced()
        p = ssm_mod.init_mamba(cfg, jax.random.key(0))
        x = jnp.asarray(0.5 * RNG.standard_normal((1, 32, cfg.d_model)),
                        jnp.float32)
        y8 = ssm_mod.mamba_forward(cfg, p, x, chunk=8)
        y32 = ssm_mod.mamba_forward(cfg, p, x, chunk=32)
        np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), atol=1e-5,
                                   rtol=1e-5)


class TestXlstmOracle:
    def test_mlstm_chunkwise_matches_stepwise(self):
        cfg = get_config("xlstm_13b").reduced()
        p = xlstm_mod.init_mlstm(cfg, jax.random.key(0))
        b, s = 2, 24
        x = jnp.asarray(0.5 * RNG.standard_normal((b, s, cfg.d_model)),
                        jnp.float32)
        y_par, state = xlstm_mod.mlstm_forward(cfg, p, x, return_state=True)

        cache = xlstm_mod.init_mlstm_cache(cfg, b)
        ys = []
        for t in range(s):
            yt, cache = xlstm_mod.mlstm_decode(cfg, p, x[:, t: t + 1], cache)
            ys.append(yt)
        y_seq = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                                   atol=5e-4, rtol=5e-3)
        np.testing.assert_allclose(np.asarray(state["C"]),
                                   np.asarray(cache["C"]), atol=5e-4,
                                   rtol=5e-3)

    def test_slstm_forward_matches_decode(self):
        cfg = get_config("xlstm_13b").reduced()
        p = xlstm_mod.init_slstm(cfg, jax.random.key(0))
        b, s = 2, 16
        x = jnp.asarray(0.5 * RNG.standard_normal((b, s, cfg.d_model)),
                        jnp.float32)
        y_fwd, state = xlstm_mod.slstm_forward(cfg, p, x, return_state=True)
        cache = xlstm_mod.init_slstm_cache(cfg, b)
        ys = []
        for t in range(s):
            yt, cache = xlstm_mod.slstm_decode(cfg, p, x[:, t: t + 1], cache)
            ys.append(yt)
        np.testing.assert_allclose(np.asarray(y_fwd),
                                   np.asarray(jnp.concatenate(ys, axis=1)),
                                   atol=1e-5, rtol=1e-5)

    def test_mlstm_forget_gate_decay(self):
        """With a strongly negative forget gate (and the exp input gate
        neutralised — otherwise a large input legitimately dominates the
        matrix memory), early-token perturbations must decay away."""
        cfg = get_config("xlstm_13b").reduced()
        p = xlstm_mod.init_mlstm(cfg, jax.random.key(0))
        p = dict(p, f_bias=jnp.full_like(p["f_bias"], -8.0),
                 w_i=jnp.zeros_like(p["w_i"]))
        x = jnp.asarray(RNG.standard_normal((1, 32, cfg.d_model)), jnp.float32)
        x2 = x.at[:, :8].set(x[:, :8] + 1.0)  # perturb early tokens only
        y1 = xlstm_mod.mlstm_forward(cfg, p, x)
        y2 = xlstm_mod.mlstm_forward(cfg, p, x2)
        late1, late2 = np.asarray(y1[:, -1]), np.asarray(y2[:, -1])
        assert np.abs(late1 - late2).max() < 1e-2
