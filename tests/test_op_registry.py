"""Declarative operator registry: OpDef-derived spaces, signatures, zoo.

The compat contract this file pins: every schedule-DB record, serving
snapshot, and golden release written before the registry refactor must keep
loading unchanged, so the four legacy operator signatures are asserted
byte-for-byte (satellite: signatures now serialize bool/str attrs too, and
must not have moved the legacy ints). Plus the loud-truncation contract of
``Space.enumerate``, generic property tests over every registered family
(signature roundtrip, tile divisibility, well-formed ``Program``), bundling
skip reasons, and a per-family smoke tune on all three hardware targets.
"""
import pytest

from repro.core import cost_model, op_registry, tuner
from repro.core.op_registry import BundleSkip, parse_signature
from repro.core.spaces import (
    BatchMatmulSpace,
    Conv2dSpace,
    DepthwiseConv2dSpace,
    MatmulSpace,
)
from repro.core.tir import Loop, Program
from repro.hw import get_target
from repro.tuna.db import ScheduleDatabase

TARGETS = ("tpu_v5e", "cpu_avx2", "gpu_a100")

# Byte-for-byte pins of the pre-registry signature grammar: these strings
# are the ``op`` keys of existing schedule DBs, snapshots, and golden
# releases.  Changing any of them orphans stored records — bump
# COST_MODEL_VERSION and write a migration instead.
LEGACY_SIGNATURES = {
    MatmulSpace(4096, 4096, 4096, 2):
        "matmul[K=4096,M=4096,N=4096,dtype_bytes=2]",
    BatchMatmulSpace(8, 128, 128, 64):
        "batch_matmul[Bsz=8,K=64,M=128,N=128,dtype_bytes=4]",
    Conv2dSpace(1, 14, 14, 256, 256):
        "conv2d[Cin=256,Cout=256,H=14,KH=3,KW=3,N=1,W=14,dtype_bytes=4]",
    DepthwiseConv2dSpace(1, 28, 28, 128):
        "depthwise_conv2d[C=128,H=28,KH=3,KW=3,N=1,W=28,dtype_bytes=4]",
}

# which knob must divide which shape attr, per family (the generators are
# all divisor-restricted; this pins that they stay so)
DIVIDES = {
    "matmul": {"bm": "M", "bn": "N", "bk": "K"},
    "batch_matmul": {"bm": "M", "bn": "N", "bk": "K"},
    "conv2d": {"b_oc": "Cout", "b_ow": "W", "b_ic": "Cin"},
    "depthwise_conv2d": {"b_c": "C"},
    "moe_dispatch": {"bm": "C", "bn": "F", "bk": "D"},
    "ssm_scan": {"chunk": "S", "b_d": "D"},
    "mlstm_chunk": {"br": "R", "bh": "dh"},
    "flash": {"block_q": "s", "block_k": "s"},
    "flash_gqa": {"block_q": "s", "block_k": "s"},
}


def _first_preset(family):
    for name, (fam, preset) in op_registry.all_presets().items():
        if fam == family:
            return name, preset
    raise AssertionError(f"family {family} has no registered preset")


class TestLegacySignatures:
    def test_four_legacy_signatures_byte_for_byte(self):
        for space, sig in LEGACY_SIGNATURES.items():
            assert space.signature() == sig

    @pytest.mark.parametrize("kind", ["tpu", "cpu", "gpu"])
    def test_signature_independent_of_target_kind(self, kind):
        sp = MatmulSpace(512, 512, 512, 4, target_kind=kind)
        assert sp.signature() == "matmul[K=512,M=512,N=512,dtype_bytes=4]"

    def test_signature_excludes_knobs_and_bookkeeping(self):
        sp = MatmulSpace(256, 256, 256)
        sp._scratch = 7  # underscore attrs never leak into the signature
        assert sp.signature() == "matmul[K=256,M=256,N=256,dtype_bytes=4]"
        assert "knobs" not in sp.signature()
        assert "target_kind" not in sp.signature()


class TestSignatureValueGrammar:
    def test_bool_attrs_serialize_and_sort(self):
        gqa = op_registry.make_space(
            "flash_gqa", {"s": 512, "d": 64, "hq": 8, "hkv": 2}, "tpu")
        assert gqa.signature() == (
            "flash_gqa[causal=True,d=64,dtype_bytes=2,hkv=2,hq=8,s=512]")
        off = op_registry.make_space(
            "flash_gqa",
            {"s": 512, "d": 64, "hq": 8, "hkv": 2, "causal": False}, "tpu")
        assert "causal=False" in off.signature()

    def test_parse_signature_value_types(self):
        name, attrs = parse_signature(
            "flash_gqa[causal=True,d=64,dtype_bytes=2,hkv=2,hq=8,s=512]")
        assert name == "flash_gqa"
        assert attrs["causal"] is True  # bool, not int, not the str "True"
        assert attrs["d"] == 64 and isinstance(attrs["d"], int)

    def test_signature_roundtrip_preserves_bools(self):
        sp = op_registry.make_space(
            "flash_gqa",
            {"s": 256, "d": 64, "hq": 4, "hkv": 4, "causal": False}, "tpu")
        back = op_registry.space_from_signature(sp.signature(), "tpu")
        assert back is not None
        assert back.signature() == sp.signature()

    def test_unknown_and_malformed_signatures_return_none(self):
        assert op_registry.space_from_signature("cell[L=4]", "cpu") is None
        assert op_registry.space_from_signature("not a sig", "cpu") is None
        assert op_registry.space_from_signature("matmul[M=12", "cpu") is None


class TestEnumerationTruncation:
    def test_full_enumeration_not_truncated(self):
        sp = MatmulSpace(256, 256, 256, target_kind="cpu")
        cfgs = list(sp.enumerate(None))
        assert len(cfgs) == sp.size()
        assert sp.enumeration_truncated is False

    def test_truncation_is_loud_and_size_exposed(self, capsys):
        sp = MatmulSpace(1024, 1024, 1024, target_kind="cpu")
        total = sp.size()
        cfgs = list(sp.enumerate(limit=7))
        err = capsys.readouterr().err
        assert len(cfgs) == 7
        assert sp.enumeration_truncated is True
        assert sp.signature() in err
        assert "truncated to 7" in err and str(total) in err

    def test_limit_covering_space_is_silent(self, capsys):
        sp = MatmulSpace(128, 128, 128, target_kind="tpu")
        cfgs = list(sp.enumerate(limit=sp.size()))
        assert len(cfgs) == sp.size()
        assert sp.enumeration_truncated is False
        assert capsys.readouterr().err == ""


class TestRegistryProperties:
    @pytest.mark.parametrize("family", sorted(DIVIDES))
    def test_every_registered_family_has_property_coverage(self, family):
        assert family in op_registry.families()

    def test_divides_map_covers_registry(self):
        # a new register() call must add a DIVIDES row here
        assert set(op_registry.families()) == set(DIVIDES)

    @pytest.mark.parametrize("family", sorted(DIVIDES))
    @pytest.mark.parametrize("kind", ["tpu", "cpu", "gpu"])
    def test_signature_and_knob_roundtrip(self, family, kind):
        _, preset = _first_preset(family)
        sp = op_registry.make_space(family, preset.attrs, kind)
        back = op_registry.space_from_signature(sp.signature(), kind)
        assert back is not None
        assert back.signature() == sp.signature()
        assert back.knobs == sp.knobs

    @pytest.mark.parametrize("family", sorted(DIVIDES))
    def test_enumerated_configs_divide_their_shapes(self, family):
        _, preset = _first_preset(family)
        sp = op_registry.make_space(family, preset.attrs, preset.kind)
        attrs = sp.attr_values()
        for cfg in sp.enumerate(256):
            for knob, shape_attr in DIVIDES[family].items():
                assert attrs[shape_attr] % cfg[knob] == 0, (
                    f"{family}: {knob}={cfg[knob]} does not divide "
                    f"{shape_attr}={attrs[shape_attr]}")

    @pytest.mark.parametrize("family", sorted(DIVIDES))
    @pytest.mark.parametrize("target_name", TARGETS)
    def test_instantiate_yields_wellformed_program(self, family,
                                                   target_name):
        target = get_target(target_name)
        _, preset = _first_preset(family)
        sp = op_registry.make_space(family, preset.attrs, target.kind)
        prog, meta = sp.instantiate(sp.default_config())
        assert isinstance(prog, Program)
        assert prog.roots

        def walk(stmt):
            if isinstance(stmt, Loop):
                assert isinstance(stmt.extent, int) and stmt.extent >= 1
                for s in stmt.body:
                    walk(s)

        for root in prog.roots:
            walk(root)
        score = cost_model.evaluate(prog, target, meta)
        assert score > 0 and score < float("inf")


class TestBundling:
    def test_unknown_family_skips_with_reason(self):
        with pytest.raises(BundleSkip, match="no Pallas kernel"):
            op_registry.bundle_for("conv2d[foo=1]", {})

    def test_malformed_signature_skips(self):
        with pytest.raises(BundleSkip):
            op_registry.bundle_for("???", {})

    def test_flash_gqa_bundles_grouped_kv_shapes(self):
        spec = op_registry.bundle_for(
            "flash_gqa[causal=True,d=64,dtype_bytes=2,hkv=2,hq=8,s=512]",
            {"block_q": 128, "block_k": 128})
        assert spec.kernel == "flash"
        shapes = [a[0] for a in spec.in_avals]
        assert shapes == [(1, 8, 512, 64), (1, 2, 512, 64), (1, 2, 512, 64)]
        assert spec.params["causal"] is True

    def test_flash_gqa_ragged_groups_skip(self):
        with pytest.raises(BundleSkip, match="multiple"):
            op_registry.bundle_for(
                "flash_gqa[causal=True,d=64,dtype_bytes=2,hkv=3,hq=8,s=512]",
                {"block_q": 128, "block_k": 128})


class TestSmokeTuneAllTargets:
    @pytest.mark.parametrize("family", sorted(DIVIDES))
    def test_family_tunes_on_all_three_targets(self, family, tmp_path):
        """One preset per family, tuned (tiny ES budget) on cpu/tpu/gpu —
        a record must land in the DB under the registry signature."""
        db = ScheduleDatabase(tmp_path / "db.jsonl")
        _, preset = _first_preset(family)
        for target_name in TARGETS:
            target = get_target(target_name)
            sp = op_registry.make_space(family, preset.attrs, target.kind)
            res = tuner.tune(sp, target, iterations=2, population=4,
                             workers=1, db=db)
            assert res.score > 0 and res.score < float("inf")
            rec = db.best(sp.signature(), target.name)
            assert rec is not None
            assert rec.config == res.config


class TestLearnedFeatureLayout:
    def test_knob_union_keeps_legacy_prefix(self):
        """The learned ranker's knob feature columns must keep the
        pre-registry layout as a prefix so old artifacts stay alignable."""
        names = [kf.name for kf in op_registry.knob_feature_union()]
        legacy = ["bm", "bn", "bk", "b_oc", "b_ow", "b_ic", "b_c"]
        assert names[:len(legacy)] == legacy
