"""Pipeline parallelism over the pod axis: numeric equivalence + schedule
shape (runs in a subprocess: needs >1 host device)."""
import json
import subprocess
import sys

import pytest

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.parallel.pipeline import pipeline_apply

mesh = make_mesh((4, 2), ("pod", "model"))
n_stages, n_micro, mb, d = 4, 6, 3, 16

rng = np.random.default_rng(0)
w = jnp.asarray(rng.standard_normal((n_stages, d, d)) * 0.3, jnp.float32)
b = jnp.asarray(rng.standard_normal((n_stages, d)) * 0.1, jnp.float32)
params = {"w": w, "b": b}
x = jnp.asarray(rng.standard_normal((n_micro, mb, d)), jnp.float32)

def stage_fn(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])

# sequential reference: apply all stages in order to each microbatch
ref = x
for s in range(n_stages):
    ref = jnp.tanh(ref @ w[s] + b[s])

with mesh:
    out = jax.jit(
        lambda p, xs: pipeline_apply(stage_fn, p, xs, mesh=mesh, axis="pod")
    )(params, x)
err = float(jnp.abs(out - ref).max())
assert err < 1e-5, err

# differentiable (GPipe backward comes from scan+ppermute transpose)
def loss(p, xs):
    return (pipeline_apply(stage_fn, p, xs, mesh=mesh, axis="pod") ** 2).sum()

with mesh:
    g = jax.jit(jax.grad(loss))(params, x)

def ref_loss(p, xs):
    h = xs
    for s in range(n_stages):
        h = jnp.tanh(h @ p["w"][s] + p["b"][s])
    return (h ** 2).sum()

g_ref = jax.grad(ref_loss)(params, x)
gerr = max(float(jnp.abs(a - b).max()) for a, b in
           zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)))
assert gerr < 1e-3, gerr
print("PIPELINE_OK", err, gerr)
"""


@pytest.mark.slow
def test_pipeline_equivalence_and_grad():
    proc = subprocess.run(
        [sys.executable, "-c", CODE], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/tmp",
             "JAX_PLATFORMS": "cpu"},
        timeout=420,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "PIPELINE_OK" in proc.stdout
