"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.tir import (
    Access,
    Compute,
    LinExpr,
    Loop,
    Program,
    TensorDecl,
    distinct_values,
)
from repro.core.locality import analyze_locality
from repro.optim import adamw

settings.register_profile("ci", max_examples=60, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# footprint arithmetic: exact vs brute force on tiling-like decompositions
# ---------------------------------------------------------------------------


@st.composite
def tiling_pairs(draw):
    """Regular tilings: strides = running products of inner extents (the only
    decompositions our schedule spaces emit)."""
    depth = draw(st.integers(1, 4))
    extents = [draw(st.integers(1, 6)) for _ in range(depth)]
    pairs = []
    stride = 1
    for n in extents:
        pairs.append((stride, n))
        stride *= n
    return pairs


@given(tiling_pairs())
def test_distinct_values_exact_for_tilings(pairs):
    got = distinct_values(pairs)
    vals = {0}
    for c, n in pairs:
        vals = {v + c * i for v in vals for i in range(n)}
    assert got == len(vals)


@given(st.lists(st.tuples(st.integers(1, 8), st.integers(1, 5)), min_size=1,
                max_size=4))
def test_distinct_values_bounds(pairs):
    """For arbitrary strides: between max extent and product of extents, and
    never exceeds span+1."""
    got = distinct_values(pairs)
    prod = 1
    span = 0
    for c, n in pairs:
        prod *= n
        span += c * (n - 1)
    assert 1 <= got <= prod
    assert got <= span + 1
    # exact-enumeration sanity (small spaces only)
    if prod <= 4096:
        vals = {0}
        for c, n in pairs:
            vals = {v + c * i for v in vals for i in range(n)}
        assert got >= max(len(vals) // 2, 1)  # approximation stays sane
        assert got <= span + 1


# ---------------------------------------------------------------------------
# locality model invariants over random tiled matmuls
# ---------------------------------------------------------------------------


@st.composite
def tiled_matmul(draw):
    bm = draw(st.sampled_from([4, 8, 16]))
    bn = draw(st.sampled_from([4, 8, 16]))
    bk = draw(st.sampled_from([4, 8, 16]))
    reps = draw(st.integers(1, 4))
    M, N, K = bm * reps, bn * reps, bk * reps
    A = TensorDecl("A", (M, K), 4)
    B = TensorDecl("B", (K, N), 4)
    C = TensorDecl("C", (M, N), 4)
    stmt = Compute(
        "fma",
        output=Access("C", (LinExpr.of(("it", bm), ("i", 1)),
                            LinExpr.of(("jt", bn), ("j", 1))), is_store=True),
        inputs=(
            Access("A", (LinExpr.of(("it", bm), ("i", 1)),
                         LinExpr.of(("kt", bk), ("k", 1)))),
            Access("B", (LinExpr.of(("kt", bk), ("k", 1)),
                         LinExpr.of(("jt", bn), ("j", 1)))),
        ),
    )
    nest = Loop("it", M // bm, (Loop("jt", N // bn, (Loop("kt", K // bk, (
        Loop("i", bm, (Loop("k", bk, (Loop("j", bn, (stmt,)),)),)),)),)),))
    return Program((A, B, C), (nest,)), (M, N, K)


@given(tiled_matmul(), st.sampled_from([64, 512, 4096, 2**20]))
def test_movement_at_least_footprint_compulsory(pm, cache):
    prog, (M, N, K) = pm
    rep = analyze_locality(prog, cache)
    total = (M * K + K * N + M * N) * 4
    assert rep.footprint_bytes == total  # exact for matmul
    # compulsory misses: every element crosses the boundary at least once
    assert rep.movement_bytes >= rep.footprint_bytes - 1e-6


@given(tiled_matmul())
def test_infinite_cache_movement_equals_footprint(pm):
    prog, _ = pm
    rep = analyze_locality(prog, 2**40)
    assert rep.movement_bytes == rep.footprint_bytes


@given(tiled_matmul(), st.tuples(st.sampled_from([64, 256, 1024, 8192]),
                                 st.sampled_from([64, 256, 1024, 8192])))
def test_movement_monotone_in_cache(pm, caches):
    prog, _ = pm
    c1, c2 = min(caches), max(caches)
    assert (analyze_locality(prog, c1).movement_bytes
            >= analyze_locality(prog, c2).movement_bytes - 1e-6)


# ---------------------------------------------------------------------------
# int8 quantisation properties
# ---------------------------------------------------------------------------


@given(st.integers(1, 6), st.integers(1, 4), st.floats(0.01, 100.0),
       st.integers(0, 2**31 - 1))
def test_int8_roundtrip_bound(rows, blocks, scale, seed):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((rows, blocks * 128)) * scale).astype(np.float32)
    import jax.numpy as jnp

    q = adamw.quantize_i8(jnp.asarray(x))
    back = np.asarray(adamw.dequantize_i8(q))
    b = x.reshape(rows, blocks, 128)
    bound = np.abs(b).max(-1, keepdims=True) / 253.9 + 1e-7
    assert (np.abs(back.reshape(rows, blocks, 128) - b) <= bound).all()


@given(st.integers(0, 2**31 - 1))
def test_int8_idempotent(seed):
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, 256)), jnp.float32)
    once = adamw.dequantize_i8(adamw.quantize_i8(x))
    twice = adamw.dequantize_i8(adamw.quantize_i8(once))
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice), atol=1e-6)


# ---------------------------------------------------------------------------
# data pipeline: shard disjointness for arbitrary shardings
# ---------------------------------------------------------------------------


@given(st.integers(1, 4).map(lambda k: 2 ** k), st.integers(0, 1000),
       st.integers(1, 64))
def test_synthetic_shards_partition(num_shards, step, vocab_scale):
    from repro.data.synthetic import SyntheticConfig, SyntheticTokens

    cfg = SyntheticConfig(vocab=vocab_scale * 61, seq_len=9,
                          global_batch=num_shards * 3)
    whole = SyntheticTokens(cfg).batch(step)["tokens"]
    parts = [
        SyntheticTokens(cfg, shard=i, num_shards=num_shards).batch(step)["tokens"]
        for i in range(num_shards)
    ]
    np.testing.assert_array_equal(np.concatenate(parts, 0), whole)
