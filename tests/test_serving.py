"""Continuous-batching engine: per-slot positions, parity, honest accounting."""
import copy

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.launch.engine import (ContinuousEngine, Request,
                                 greedy_decode_reference, latency_summary)
from repro.launch.serve import group_into_waves, serve
from repro.models.model import Model

RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("yi_6b").reduced()
    model = Model(cfg)
    return cfg, model, model.init(jax.random.key(0))


def mixed_requests(vocab, spec):
    """spec: list of (prompt_len, max_new)."""
    return [Request(i, list(RNG.integers(0, vocab, plen)), mnew)
            for i, (plen, mnew) in enumerate(spec)]


class TestVectorPos:
    def test_decode_step_vector_pos_matches_scalar_calls(self, model_and_params):
        """decode_step with a [B] pos vector == B independent scalar-pos
        calls on the per-row cache slices (logits and cache writes)."""
        cfg, model, params = model_and_params
        B, cap = 3, 12
        positions = np.array([2, 7, 0], np.int32)
        toks = jnp.asarray(RNG.integers(0, cfg.vocab, B), jnp.int32)
        # a non-trivial cache: prefill a length-8 batch, then pretend each
        # row sits at its own depth
        batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (B, 8)),
                                       jnp.int32)}
        cache, _, _ = model.prefill(params, batch, cap)

        vec_logits, vec_cache = model.decode_step(
            params, cache, toks, jnp.asarray(positions))

        for i in range(B):
            row_cache = jax.tree.map(lambda a: a[:, i: i + 1], cache)
            lg, nc = model.decode_step(
                params, row_cache, toks[i: i + 1],
                jnp.asarray(positions[i], jnp.int32))
            np.testing.assert_allclose(np.asarray(lg[0]),
                                       np.asarray(vec_logits[i]),
                                       atol=1e-5, rtol=1e-5)
            for a, b in zip(jax.tree.leaves(nc),
                            jax.tree.leaves(jax.tree.map(
                                lambda a: a[:, i: i + 1], vec_cache))):
                np.testing.assert_allclose(np.asarray(a, np.float32),
                                           np.asarray(b, np.float32),
                                           atol=1e-5, rtol=1e-5)

    def test_scalar_pos_path_unchanged(self, model_and_params):
        """Scalar pos must still take the lockstep path (wave fallback)."""
        cfg, model, params = model_and_params
        batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (2, 8)),
                                       jnp.int32)}
        cache, pos, _ = model.prefill(params, batch, 12)
        toks = jnp.asarray(RNG.integers(0, cfg.vocab, 2), jnp.int32)
        lg_s, _ = model.decode_step(params, cache, toks, pos)
        lg_v, _ = model.decode_step(
            params, cache, toks, jnp.full((2,), int(pos), jnp.int32))
        np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_v),
                                   atol=1e-5, rtol=1e-5)


class TestGreedyParity:
    """Acceptance: continuous output is token-for-token identical to the
    wave scheduler and to one-request-at-a-time sequential decode, across
    mixed prompt lengths and mixed max_new."""

    SPEC = [(4, 3), (8, 6), (4, 5), (8, 2), (12, 4), (4, 6), (12, 7)]

    def test_continuous_matches_wave_and_sequential(self, model_and_params):
        cfg, model, params = model_and_params
        base = mixed_requests(cfg.vocab, self.SPEC)
        cap = max(len(r.prompt) + r.max_new for r in base) + 2

        cont = copy.deepcopy(base)
        serve(model, params, cont, slots=3, cap=cap, scheduler="continuous")
        wave = copy.deepcopy(base)
        serve(model, params, wave, slots=3, cap=cap, scheduler="wave")

        for r in cont:
            assert len(r.out) == r.max_new
        cont_out = {r.rid: r.out for r in cont}
        assert cont_out == {r.rid: r.out for r in wave}
        seq_out = {r.rid: greedy_decode_reference(model, params, r.prompt,
                                                  r.max_new, cap)
                   for r in base}
        assert cont_out == seq_out


class TestAccounting:
    def test_wave_pad_slots_reported_as_waste(self, model_and_params):
        """A 3-request wave on a 4-slot engine: the pad row's decode work
        must land in wasted_slot_steps, never in slot_steps."""
        cfg, model, params = model_and_params
        reqs = mixed_requests(cfg.vocab, [(6, 5)] * 3)
        stats = serve(model, params, reqs, slots=4, cap=16, scheduler="wave")
        # 4 decode launches (max_new-1) x 1 pad slot; all requests live
        # the whole wave, so no finished-slot waste on top
        assert stats["engine_steps"] == 4
        assert stats["slot_steps"] == 4 * 3
        assert stats["wasted_slot_steps"] == 4
        assert stats["tokens"] == 15

    def test_wave_finished_slots_reported_as_waste(self, model_and_params):
        """Mixed max_new in one wave: the short request's idle tail counts
        as waste while the long one drains."""
        cfg, model, params = model_and_params
        reqs = mixed_requests(cfg.vocab, [(6, 2), (6, 6)])
        stats = serve(model, params, reqs, slots=2, cap=16, scheduler="wave")
        # 5 decode launches; request 0 is live for 1 of them
        assert stats["engine_steps"] == 5
        assert stats["slot_steps"] == 5 + 1
        assert stats["wasted_slot_steps"] == 4
        assert all(r.t_first is not None and r.t_done is not None
                   for r in reqs)

    def test_continuous_beats_wave_on_waste(self, model_and_params):
        """The acceptance inequality on a mixed workload: strictly fewer
        wasted slot-steps, same tokens."""
        cfg, model, params = model_and_params
        spec = [(4, 2), (4, 8), (8, 3), (8, 8), (4, 5), (8, 2)]
        base = mixed_requests(cfg.vocab, spec)
        cap = max(len(r.prompt) + r.max_new for r in base) + 2
        wave = copy.deepcopy(base)
        sw = serve(model, params, wave, slots=2, cap=cap, scheduler="wave")
        cont = copy.deepcopy(base)
        sc = serve(model, params, cont, slots=2, cap=cap,
                   scheduler="continuous")
        assert sc["tokens"] == sw["tokens"] == sum(m for _, m in spec)
        assert sc["wasted_slot_steps"] < sw["wasted_slot_steps"]
        # latency report shape
        for s in (sw, sc):
            for key in ("ttft_s", "latency_s"):
                assert set(s[key]) == {"p50", "p95", "p99", "mean"}
            assert len(s["requests"]) == len(spec)

    def test_group_into_waves_buckets_by_length(self, model_and_params):
        cfg, _, _ = model_and_params
        reqs = mixed_requests(cfg.vocab, [(4, 1), (8, 1), (4, 1), (4, 1)])
        waves = group_into_waves(reqs, slots=2)
        assert [[r.rid for r in w] for w in waves] == [[0, 2], [3], [1]]


class TestSlotLifecycle:
    def test_eos_frees_slot_early(self, model_and_params):
        """A request that emits its eos_id stops there; the freed slot is
        refilled and the remaining queue still drains correctly."""
        cfg, model, params = model_and_params
        base = mixed_requests(cfg.vocab, [(6, 6), (6, 6), (6, 6)])
        cap = 16
        ref = greedy_decode_reference(model, params, base[0].prompt, 6, cap)
        eos = ref[2]  # cut request 0 at its third emitted token
        reqs = copy.deepcopy(base)
        reqs[0].eos_id = eos
        stats = serve(model, params, reqs, slots=2, cap=cap,
                      scheduler="continuous")
        assert reqs[0].out[-1] == eos
        assert len(reqs[0].out) <= 3
        assert reqs[0].out == ref[: len(reqs[0].out)]
        for r in reqs[1:]:
            assert len(r.out) == 6
        assert stats["prefills"] == 3

    def test_deadline_truncates_and_is_counted(self, model_and_params):
        """deadline_s=0 is already past at admission: the request still
        gets its first (prefill) token, then frees the slot."""
        cfg, model, params = model_and_params
        reqs = mixed_requests(cfg.vocab, [(6, 50), (6, 4)])
        reqs[0].deadline_s = 0.0
        stats = serve(model, params, reqs, slots=1, cap=64,
                      scheduler="continuous")
        assert reqs[0].truncated and len(reqs[0].out) == 1
        assert reqs[0].t_done is not None
        assert len(reqs[1].out) == 4 and not reqs[1].truncated
        assert stats["deadline_truncations"] == 1

    def test_refresh_polled_at_admission_boundary(self, model_and_params):
        """The snapshot poll rides admissions, not the first batch."""
        cfg, model, params = model_and_params
        calls = []

        def refresh():
            calls.append(True)
            return len(calls) == 1

        reqs = mixed_requests(cfg.vocab, [(6, 3)] * 4)
        stats = serve(model, params, reqs, slots=2, cap=16,
                      scheduler="continuous", refresh=refresh)
        assert calls  # polled for the second admission batch
        assert stats["cache_reloads"] == 1


def test_latency_summary_percentiles():
    s = latency_summary([0.1] * 99 + [1.0])
    assert s["p50"] == pytest.approx(0.1)
    assert s["p99"] >= 0.1 and s["p99"] <= 1.0
    assert latency_summary([]) == {"p50": 0.0, "p95": 0.0, "p99": 0.0,
                                   "mean": 0.0}
