"""Optimizer, data pipeline, checkpointing, fault tolerance, elastic."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import store
from repro.configs.base import get_config
from repro.data.loader import PrefetchLoader
from repro.data.synthetic import SyntheticConfig, SyntheticTokens
from repro.launch.train import TrainOptions, train, train_with_recovery
from repro.optim import adamw
from repro.runtime.failure import FailureInjector, InjectedFailure
from repro.runtime.straggler import StragglerMonitor

RNG = np.random.default_rng(0)


class TestAdamW:
    def _params(self):
        return {"w": jnp.asarray(RNG.standard_normal((4, 256)), jnp.float32),
                "b": jnp.zeros((256,), jnp.float32)}

    def test_matches_reference_math(self):
        cfg = adamw.AdamWConfig(lr=1e-2, weight_decay=0.0, grad_clip=1e9)
        params = self._params()
        grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.1, params)
        state = adamw.init_state(cfg, params)
        new_params, state, _ = adamw.apply_updates(cfg, params, grads, state)
        # first step: m_hat = g, v_hat = g^2 -> update = g/(|g|+eps) = 1
        want = params["w"] - 1e-2 * 0.1 / (0.1 + cfg.eps)
        np.testing.assert_allclose(np.asarray(new_params["w"]),
                                   np.asarray(want), rtol=1e-5)

    def test_grad_clip_global_norm(self):
        cfg = adamw.AdamWConfig(lr=0.0, grad_clip=1.0)
        params = self._params()
        grads = jax.tree.map(lambda p: jnp.ones_like(p) * 100.0, params)
        _, _, metrics = adamw.apply_updates(cfg, params, grads,
                                            adamw.init_state(cfg, params))
        assert float(metrics["grad_norm"]) > 1.0  # reported pre-clip

    @pytest.mark.parametrize("dtype", ["bfloat16", "int8"])
    def test_low_precision_states_still_converge(self, dtype):
        cfg = adamw.AdamWConfig(lr=0.05, state_dtype=dtype, weight_decay=0.0)
        w = jnp.asarray(RNG.standard_normal((8, 128)), jnp.float32)
        target = jnp.zeros_like(w)
        params = {"w": w}
        state = adamw.init_state(cfg, params)
        for _ in range(60):
            grads = {"w": params["w"] - target}
            params, state, _ = adamw.apply_updates(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).mean()) < 0.2

    def test_int8_roundtrip_error_bounded(self):
        x = jnp.asarray(RNG.standard_normal((4, 512)) * 3.0, jnp.float32)
        q = adamw.quantize_i8(x)
        back = adamw.dequantize_i8(q)
        # blockwise absmax scaling: error <= scale/2 = absmax/254 per block
        blocks = np.asarray(x).reshape(4, -1, 128)
        bound = np.abs(blocks).max(-1, keepdims=True) / 254 + 1e-6
        err = np.abs(np.asarray(back).reshape(4, -1, 128) - blocks)
        assert (err <= bound).all()


class TestData:
    def test_deterministic_and_restartable(self):
        cfg = SyntheticConfig(vocab=1000, seq_len=32, global_batch=8)
        a = SyntheticTokens(cfg).batch(7)
        b = SyntheticTokens(cfg).batch(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_shards_disjoint_and_cover(self):
        cfg = SyntheticConfig(vocab=50000, seq_len=16, global_batch=8)
        whole = SyntheticTokens(cfg).batch(3)["tokens"]
        parts = [SyntheticTokens(cfg, shard=i, num_shards=4).batch(3)["tokens"]
                 for i in range(4)]
        np.testing.assert_array_equal(np.concatenate(parts, 0), whole)

    def test_labels_are_next_tokens(self):
        cfg = SyntheticConfig(vocab=1000, seq_len=32, global_batch=2)
        b = SyntheticTokens(cfg).batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_prefetch_loader_ordered(self):
        cfg = SyntheticConfig(vocab=100, seq_len=8, global_batch=2)
        src = SyntheticTokens(cfg)
        loader = PrefetchLoader(src, start_step=5)
        try:
            for want in (5, 6, 7):
                step, batch = loader.get(want)
                assert step == want
                np.testing.assert_array_equal(batch["tokens"],
                                              src.batch(want)["tokens"])
        finally:
            loader.close()


class TestCheckpoint:
    def test_save_restore_bit_exact(self, tmp_path):
        tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
        store.save(str(tmp_path), 5, tree)
        like = jax.tree.map(jnp.zeros_like, tree)
        got, manifest = store.restore(str(tmp_path), like)
        assert manifest["step"] == 5
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_latest_pointer_and_gc(self, tmp_path):
        tree = {"a": jnp.ones((2,), jnp.float32)}
        for s in (1, 2, 3, 4):
            store.save(str(tmp_path), s, tree)
        store.gc_old(str(tmp_path), keep=2)
        assert store.latest_step(str(tmp_path)) == 4
        kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
        assert len(kept) == 2

    def test_async_checkpointer(self, tmp_path):
        ck = store.AsyncCheckpointer(str(tmp_path), keep=2)
        ck.save(1, {"a": jnp.ones((4,), jnp.float32)})
        ck.wait()
        assert store.latest_step(str(tmp_path)) == 1


@pytest.mark.slow  # full train/crash/restart cycles: end-to-end, not tier-1
class TestFaultTolerance:
    def _opts(self, tmp_path, steps=12):
        return TrainOptions(steps=steps, batch=2, seq=16,
                            ckpt_dir=str(tmp_path), ckpt_every=4,
                            log_every=100)

    def test_restart_resumes_bit_exact(self, tmp_path):
        cfg = get_config("yi_6b").reduced()
        # uninterrupted run
        ref = train(cfg, TrainOptions(steps=12, batch=2, seq=16,
                                      log_every=100))
        # interrupted at step 6 (after the step-4 checkpoint), recovered
        inj = FailureInjector(fail_at_steps={6})
        out = train_with_recovery(cfg, self._opts(tmp_path), injector=inj)
        assert out["final_step"] == 12
        for a, b in zip(jax.tree.leaves(ref["params"]),
                        jax.tree.leaves(out["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=0, rtol=0)

    def test_gives_up_after_max_restarts(self, tmp_path):
        cfg = get_config("yi_6b").reduced()
        inj = FailureInjector(fail_at_steps={1, 2, 3, 4, 5, 6, 7})
        from repro.runtime.failure import RestartPolicy

        inj._fired = set()

        class AlwaysFail(FailureInjector):
            def maybe_fail(self, step, phase="step"):
                if phase == "step" and step >= 1:
                    raise InjectedFailure(f"boom {step}")

        with pytest.raises(InjectedFailure):
            train_with_recovery(cfg, self._opts(tmp_path),
                                injector=AlwaysFail(),
                                policy=RestartPolicy(max_restarts=2))

    def test_crash_during_save_leaves_valid_checkpoint(self, tmp_path):
        cfg = get_config("yi_6b").reduced()
        inj = FailureInjector(fail_during_save_at={8})
        out = train_with_recovery(cfg, self._opts(tmp_path), injector=inj)
        assert out["final_step"] == 12
        assert store.latest_step(str(tmp_path)) == 12


class TestStraggler:
    def test_flags_slow_step_and_mitigation(self):
        mon = StragglerMonitor(threshold=2.0, min_seconds=0.0,
                               persistent_after=2)
        for i in range(8):
            assert mon.record(i, 0.10) is None
        ev = mon.record(8, 0.50)
        assert ev is not None and ev.mitigation == "transient"
        ev2 = mon.record(9, 0.50, fetch_seconds=0.4)
        assert ev2.mitigation == "rebalance_data"
        ev3 = mon.record(10, 0.60)
        assert ev3.mitigation == "exclude_and_remesh"
