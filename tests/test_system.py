"""End-to-end behaviour: the public API flows a user would actually run."""
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.launch.serve import Request, serve
from repro.launch.train import TrainOptions, train
from repro.models.model import Model


class TestTrainEndToEnd:
    def test_loss_decreases_on_learnable_data(self):
        """Train on a fixed repeating sequence — CE must fall well below the
        ln(V) random floor within ~60 steps."""
        cfg = get_config("yi_6b").reduced()
        model = Model(cfg)
        from repro.optim import adamw

        opt_cfg = adamw.AdamWConfig(lr=3e-3, weight_decay=0.0)
        params = model.init(jax.random.key(0))
        state = adamw.init_state(opt_cfg, params)
        base = jnp.arange(33, dtype=jnp.int32) % cfg.vocab
        toks = jnp.tile(base[None], (4, 1))
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

        from repro.launch.steps import make_train_step

        step = jax.jit(make_train_step(model, opt_cfg))
        first = None
        for _ in range(60):
            params, state, metrics = step(params, state, batch)
            if first is None:
                first = float(metrics["ce"])
        last = float(metrics["ce"])
        assert last < first * 0.5
        assert last < 2.0  # far below ln(256) = 5.55

    def test_grad_accum_equivalent_to_large_batch(self):
        cfg = get_config("yi_6b").reduced()
        model = Model(cfg)
        from repro.launch.steps import make_train_step
        from repro.optim import adamw

        opt_cfg = adamw.AdamWConfig(lr=1e-3)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)),
                                  jnp.int32),
        }
        params = model.init(jax.random.key(0))
        state = adamw.init_state(opt_cfg, params)
        p1, _, m1 = jax.jit(make_train_step(model, opt_cfg))(params, state,
                                                             batch)
        p4, _, m4 = jax.jit(make_train_step(model, opt_cfg, accum_steps=4))(
            params, state, batch)
        assert float(m1["ce"]) == pytest.approx(float(m4["ce"]), rel=1e-4)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=2e-5)

    def test_int8_grad_compression_trains(self):
        cfg = get_config("yi_6b").reduced()
        model = Model(cfg)
        from repro.launch.steps import make_train_step
        from repro.optim import adamw

        opt_cfg = adamw.AdamWConfig(lr=1e-3)
        params = model.init(jax.random.key(0))
        state = adamw.init_state(opt_cfg, params)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                  jnp.int32),
        }
        step = jax.jit(make_train_step(model, opt_cfg,
                                       grad_compression="int8"))
        params, state, metrics = step(params, state, batch)
        assert np.isfinite(float(metrics["loss"]))


class TestServeEndToEnd:
    def test_wave_batched_serving(self):
        cfg = get_config("yi_6b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        reqs = [Request(i, list(rng.integers(0, cfg.vocab, 8)), 6)
                for i in range(4)]
        stats = serve(model, params, reqs, slots=2, cap=16)
        assert all(len(r.out) == 6 for r in reqs)
        assert stats["tokens"] == 24

    def test_greedy_decode_matches_argmax_forward(self):
        """The engine's first generated token == argmax of the prefill
        logits' last position computed by the parallel forward."""
        cfg = get_config("yi_6b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(1)
        prompt = list(rng.integers(0, cfg.vocab, 8))
        reqs = [Request(0, prompt, 2)]
        serve(model, params, reqs, slots=1, cap=12)
        batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
        logits = model.logits(params, {**batch, "labels": batch["tokens"]})
        assert reqs[0].out[0] == int(jnp.argmax(logits[0, -1]))


class TestServeHotReload:
    def test_republished_snapshot_lands_between_waves(self, tmp_path):
        """Acceptance: a running serve loop observes a republished schedule
        snapshot at a wave boundary — new records served, a fresh cache
        instance (hit counters reset) — without restarting the process."""
        from repro.core import tuner
        from repro.tuna.cache import SnapshotManager
        from repro.tuna.db import ScheduleDatabase, ScheduleRecord

        db = ScheduleDatabase(str(tmp_path / "db.jsonl"))
        db.add(ScheduleRecord(op="warm[]", target="tpu_v5e",
                              config={"bm": 64}, score=2.0))
        mgr = SnapshotManager(db.path, str(tmp_path / "snaps"))
        mgr.ensure()
        tuner.set_default_cache(mgr.latest_path)
        first = tuner.get_default_cache()
        assert first.best("warm[]", "tpu_v5e") is not None

        cfg = get_config("yi_6b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        reqs = [Request(i, list(rng.integers(0, cfg.vocab, 8)), 4)
                for i in range(4)]  # slots=2 -> two waves, one poll between

        def refresh():
            # another host re-tunes and republishes while we serve wave 1
            if tuner.get_default_cache() is first:
                db.add(ScheduleRecord(op="fresh[]", target="tpu_v5e",
                                      config={"bm": 128}, score=1.0))
                mgr.ensure()
            return tuner.refresh_default_cache()

        stats = serve(model, params, reqs, slots=2, cap=16, refresh=refresh)
        assert stats["cache_reloads"] == 1
        assert all(len(r.out) == 4 for r in reqs)
        swapped = tuner.get_default_cache()
        assert swapped is not first  # fresh instance: counters reset
        assert swapped.best("fresh[]", "tpu_v5e").config == {"bm": 128}
        assert swapped.best("warm[]", "tpu_v5e") is not None


class TestElastic:
    def test_checkpoint_reshards_across_device_counts(self, tmp_path):
        """Save params from a 1-device run, restore onto a 4-device mesh in a
        child interpreter (elastic shrink/grow path)."""
        cfg = get_config("yi_6b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        from repro.checkpoint import store

        store.save(str(tmp_path), 3, params)

        code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro.configs.base import get_config
from repro.checkpoint.elastic import restore_on_mesh
from repro.launch.mesh import make_mesh
from repro.models.model import Model

cfg = get_config("yi_6b").reduced()
model = Model(cfg)
like = jax.eval_shape(lambda: model.init(jax.random.key(0)))
mesh = make_mesh((2, 2), ("data", "model"))
tree, manifest = restore_on_mesh(r"{tmp_path}", like, mesh, kind="params")
leaf = jax.tree.leaves(tree)[0]
assert manifest["step"] == 3
assert len(leaf.sharding.device_set) >= 1
total = sum(x.size for x in jax.tree.leaves(tree))
print("ELASTIC_OK", total)
"""
        proc = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/tmp",
                 "JAX_PLATFORMS": "cpu"},
            timeout=300,
        )
        assert proc.returncode == 0, proc.stderr[-2000:]
        assert "ELASTIC_OK" in proc.stdout
