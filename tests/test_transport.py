"""Fleet transport + snapshot lifecycle: manifest-verified push/pull, the
no-shared-filesystem fleet, merge-under-concurrent-append, stale-snapshot
rejection, and hot reload of republished snapshots.

The acceptance spine: a 2-shard fleet whose shard writers and sync host
share *nothing but the transport channel* must reconcile to exactly the
single-process store, and a long-running serve process must observe a
republished snapshot without restart (the serve-loop half of that lives
in tests/test_system.py; the tuner half is here).

Like test_fleet.py, this module is imported by spawned worker processes
(the locked-writer test), so it must stay jax-free.
"""
import dataclasses
import json
import multiprocessing
import os
import time

import pytest

from repro.core import tuner
from repro.core.cost_model import COST_MODEL_VERSION
from repro.tuna import cli, fleet, orchestrator
from repro.tuna.cache import (
    POINTER_SCHEMA,
    ScheduleCache,
    SnapshotManager,
    StaleSnapshotError,
    StaleSnapshotWarning,
    read_snapshot_header,
)
from repro.tuna.db import ScheduleDatabase, ScheduleRecord
from repro.tuna.transport import (
    IntegrityError,
    LocalDirTransport,
    MemoryTransport,
    TransportError,
    resolve_transport,
)

JOB_OPS = ["dense_256", "dense_512", "batch_matmul"]
JOB_TARGETS = ["tpu_v5e", "cpu_avx2"]


def _matrix():
    return orchestrator.jobs_for(JOB_OPS, JOB_TARGETS, limit=64)


def _mem(tmp_path) -> MemoryTransport:
    """A MemoryTransport on a bucket unique to this test invocation."""
    bucket = f"test-{os.path.basename(tmp_path)}"
    MemoryTransport.wipe(bucket)
    return MemoryTransport(bucket)


@pytest.fixture(params=["dir", "mem"])
def transport(request, tmp_path):
    if request.param == "dir":
        return LocalDirTransport(str(tmp_path / "bucket"))
    return _mem(tmp_path)


def _store_with(tmp_path, name, records):
    db = ScheduleDatabase(str(tmp_path / name))
    for rec in records:
        db.add(rec)
    return db


def _rec(op="a[]", target="t0", bm=64, score=1.0):
    return ScheduleRecord(op=op, target=target, config={"bm": bm},
                          score=score, meta={"strategy": "exhaustive"})


class TestTransportProtocol:
    def test_push_pull_roundtrip_verified(self, transport, tmp_path):
        db = _store_with(tmp_path, "src.jsonl", [_rec(), _rec(op="b[]")])
        man = transport.push(db.path, "fleet.shard00.jsonl")
        assert man.records == 2 and man.size == os.path.getsize(db.path)
        assert man.cost_model_version == COST_MODEL_VERSION
        assert transport.exists("fleet.shard00.jsonl")
        assert transport.list() == ["fleet.shard00.jsonl"]  # manifest hidden
        assert transport.list_shards("fleet.jsonl") == ["fleet.shard00.jsonl"]

        out = str(tmp_path / "pulled" / "fleet.shard00.jsonl")
        got = transport.pull("fleet.shard00.jsonl", out)
        assert got == man
        assert open(out, "rb").read() == open(db.path, "rb").read()

    def test_pull_of_corrupt_blob_fails_loudly(self, transport, tmp_path):
        db = _store_with(tmp_path, "src.jsonl", [_rec()])
        transport.push(db.path, "x.jsonl")
        transport._put("x.jsonl", b'{"torn": ')  # bitrot / torn copy
        with pytest.raises(IntegrityError, match="torn or corrupt"):
            transport.pull("x.jsonl", str(tmp_path / "out.jsonl"))
        assert not os.path.exists(tmp_path / "out.jsonl")  # nothing landed

    def test_missing_object_and_manifest(self, transport, tmp_path):
        with pytest.raises(TransportError, match="no object"):
            transport.pull("nope.jsonl", str(tmp_path / "out"))
        transport._put("bare.jsonl", b"{}\n")  # pushed out-of-band: no manifest
        with pytest.raises(TransportError, match="no manifest"):
            transport.pull("bare.jsonl", str(tmp_path / "out"))

    def test_mid_push_blob_is_not_yet_visible(self, transport, tmp_path):
        """The manifest is pushed last and acts as the commit marker: a
        sync racing a mid-push shard must see 'not pushed yet' (skip),
        never pull a payload whose manifest hasn't landed."""
        transport._put("f.shard00.jsonl", b'{"op": "a[]"}\n')  # payload only
        assert not transport.exists("f.shard00.jsonl")
        rep = fleet.sync(str(tmp_path / "sync" / "f.jsonl"), 1,
                         transport=transport)
        assert rep.skipped == ["f.shard00.jsonl"] and rep.pulled == []

    def test_repush_replaces_payload_and_manifest_coherently(
            self, transport, tmp_path):
        """A crashed shard host re-running `tune --transport` re-pushes its
        store: the pull side must get the new payload verified against the
        new manifest, never a fresh-payload/stale-manifest pair."""
        db = _store_with(tmp_path, "src.jsonl", [_rec()])
        first = transport.push(db.path, "f.shard00.jsonl")
        db.add(_rec(op="more[]", bm=256, score=0.5))
        second = transport.push(db.path, "f.shard00.jsonl")
        assert second.sha1 != first.sha1 and second.records == 2
        out = str(tmp_path / "out.jsonl")
        assert transport.pull("f.shard00.jsonl", out) == second
        assert open(out, "rb").read() == open(db.path, "rb").read()

    def test_memory_buckets_shared_by_name_isolated_by_bucket(self, tmp_path):
        a1, a2 = MemoryTransport("bkt-a"), MemoryTransport("bkt-a")
        b = MemoryTransport("bkt-b")
        try:
            db = _store_with(tmp_path, "s.jsonl", [_rec()])
            a1.push(db.path, "s.jsonl")
            assert a2.exists("s.jsonl")  # same channel, different "host"
            assert not b.exists("s.jsonl")
        finally:
            MemoryTransport.wipe("bkt-a")
            MemoryTransport.wipe("bkt-b")

    def test_resolve_transport_specs(self, tmp_path):
        t = resolve_transport(f"dir://{tmp_path}/bucket")
        assert isinstance(t, LocalDirTransport)
        assert resolve_transport(str(tmp_path)).root == str(tmp_path)
        m = resolve_transport("mem://spec-test")
        assert isinstance(m, MemoryTransport) and m.bucket == "spec-test"
        assert resolve_transport(m) is m
        with pytest.raises(ValueError):
            resolve_transport("")

    def test_dir_transport_rejects_escaping_names(self, tmp_path):
        t = LocalDirTransport(str(tmp_path / "bucket"))
        with pytest.raises(TransportError, match="escapes"):
            t._put("../outside.jsonl", b"x")


class _RepushRacingTransport(MemoryTransport):
    """Retracts the manifest between the caller's exists() and pull() —
    what a concurrent re-push's commit window looks like to a sync."""

    def pull(self, name, local_path):
        self._delete(name + ".manifest")
        return super().pull(name, local_path)


class TestFleetOverTransport:
    def test_sync_skips_shard_repushed_mid_window(self, tmp_path):
        """sync racing a shard re-push treats the shard as not-pushed-yet
        (skipped, merged on the next sync) instead of aborting the whole
        merge — but a genuinely corrupt blob still fails loudly."""
        bucket = f"race-{os.path.basename(tmp_path)}"
        MemoryTransport.wipe(bucket)
        db = _store_with(tmp_path, "src.jsonl", [_rec()])
        _RepushRacingTransport(bucket).push(db.path, "f.shard00.jsonl")
        rep = fleet.sync(str(tmp_path / "sync" / "f.jsonl"), 1,
                         transport=_RepushRacingTransport(bucket))
        assert rep.skipped == ["f.shard00.jsonl"] and rep.pulled == []

        clean = MemoryTransport(bucket)
        clean.push(db.path, "f.shard00.jsonl")
        clean._put("f.shard00.jsonl", b"bitrot")  # manifest now lies
        with pytest.raises(IntegrityError):
            fleet.sync(str(tmp_path / "sync2" / "f.jsonl"), 1,
                       transport=clean)
        MemoryTransport.wipe(bucket)

    def test_unsharded_tune_push_is_reachable_by_sync(self, tmp_path,
                                                      capsys):
        """`tune --transport` without sharding must push under the shard-0
        object name — `sync --transport` only ever pulls shard names, so a
        base-named push would be silently unreachable."""
        bucket = f"mem://cli-{os.path.basename(tmp_path)}"
        MemoryTransport.wipe(bucket[len("mem://"):])
        db = str(tmp_path / "host" / "db.jsonl")
        rc = cli.main(["tune", "--smoke", "--workers", "1", "--db", db,
                       "--transport", bucket])
        assert rc == 0
        assert "pushed db.shard00.jsonl" in capsys.readouterr().out
        rep = fleet.sync(str(tmp_path / "sync" / "db.jsonl"), 1,
                         transport=bucket)
        assert rep.pulled == ["db.shard00.jsonl"] and rep.skipped == []
        assert rep.keys == len(ScheduleDatabase(db))

    def test_two_shard_fleet_no_shared_fs_matches_single_run(self, tmp_path):
        """Acceptance: shard hosts and the sync host share nothing but the
        channel. Late shards are skipped and a re-sync completes; the
        merged store is record-for-record identical to both a
        single-process run and a shared-filesystem fleet sync."""
        jobs = _matrix()
        single = ScheduleDatabase(str(tmp_path / "single.jsonl"))
        assert orchestrator.run(jobs, db=single, workers=1).ok

        t = _mem(tmp_path)
        # every host uses a private directory — no shared base path
        a = fleet.run_shard(jobs, 2, 0, str(tmp_path / "hostA" / "f.jsonl"),
                            transport=t, workers=1)
        assert a.ok and a.pushed is not None
        assert a.pushed.name == "f.shard00.jsonl"

        # shard 1 hasn't pushed yet: sync sees it as missing, not an error
        sync_base = str(tmp_path / "hostC" / "f.jsonl")
        partial = fleet.sync(sync_base, 2, transport=t)
        assert partial.skipped == ["f.shard01.jsonl"]
        assert partial.pulled == ["f.shard00.jsonl"]
        assert 0 < partial.keys < len(single)

        b = fleet.run_shard(jobs, 2, 1, str(tmp_path / "hostB" / "f.jsonl"),
                            transport=t, workers=1)
        assert b.ok and b.pushed.name == "f.shard01.jsonl"
        full = fleet.sync(sync_base, 2, transport=t)
        assert full.skipped == [] and full.corrupt_lines == 0
        assert fleet.divergence(full.db, single, "fleet", "single") == []

        # record-for-record parity with the shared-fs flow, provenance
        # stamps included (staged pulls keep the shard store basename);
        # only the per-run tuned_at wall-clock stamp may differ
        shared_base = str(tmp_path / "sharedfs" / "f.jsonl")
        fleet.run_fleet(jobs, 2, shared_base, workers=1)
        shared = fleet.sync(shared_base, 2)

        def _no_clock(db):
            return [
                dataclasses.replace(
                    r, meta={k: v for k, v in r.meta.items()
                             if k != "tuned_at"})
                for r in db.records()
            ]

        assert _no_clock(full.db) == _no_clock(shared.db)

        # re-sync over the channel is idempotent
        again = fleet.sync(sync_base, 2, transport=t)
        assert again.db.records() == full.db.records()


# -- merge under concurrent append (the flock + corrupt-line fixes) --------

def _locked_slow_writer(path: str, line: str, hold_seconds: float) -> None:
    """Acquire the store flock, expose a torn prefix, then finish the line
    and release — what an in-flight shard writer looks like mid-append."""
    import fcntl

    fd = os.open(path, os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644)
    fcntl.flock(fd, fcntl.LOCK_EX)
    half = len(line) // 2
    os.write(fd, line[:half].encode())
    with open(path + ".lock-held", "w"):
        pass  # signal the parent that the torn state is on disk
    time.sleep(hold_seconds)
    os.write(fd, line[half:].encode())
    os.close(fd)  # releases the flock


class TestMergeUnderConcurrentAppend:
    def test_locked_merge_waits_for_inflight_writer(self, tmp_path):
        """sync must not count a still-being-written final line as corrupt:
        the source flock makes merge wait out the writer, so the record is
        kept — previously it was silently dropped while sync reported
        success."""
        pytest.importorskip("fcntl")
        base = str(tmp_path / "f.jsonl")
        shard = fleet.shard_store_path(base, 0)
        keep = _rec(op="keep[]", bm=128, score=0.5)
        ScheduleDatabase(shard).add(_rec(op="first[]"))

        ctx = multiprocessing.get_context("spawn")
        proc = ctx.Process(target=_locked_slow_writer,
                           args=(shard, keep.to_json() + "\n", 1.0))
        proc.start()
        try:
            deadline = time.monotonic() + 20
            while not os.path.exists(shard + ".lock-held"):
                assert time.monotonic() < deadline, "writer never locked"
                time.sleep(0.01)
            rep = fleet.sync(base, 1)  # blocks on the source flock
        finally:
            proc.join(timeout=30)
        assert proc.exitcode == 0
        assert rep.corrupt_lines == 0
        assert rep.db.best("keep[]", "t0").config == {"bm": 128}

    def test_torn_line_reported_then_recovered_by_resync(self, tmp_path):
        """A genuinely torn line (writer crashed mid-append) is dropped but
        *reported* — and once the shard host re-runs and completes the
        record, re-sync absorbs it."""
        base = str(tmp_path / "f.jsonl")
        shard = fleet.shard_store_path(base, 0)
        good, torn = _rec(op="good[]"), _rec(op="late[]", bm=256, score=0.25)
        with open(shard, "w") as f:
            f.write(good.to_json() + "\n")
            f.write(torn.to_json()[: 20])  # crash mid-write, no newline
        rep = fleet.sync(base, 1)
        assert rep.corrupt_lines == 1
        assert rep.corrupt[shard] == 1
        assert rep.db.best("late[]", "t0") is None

        with open(shard, "w") as f:  # the shard host re-runs its slice
            f.write(good.to_json() + "\n")
            f.write(torn.to_json() + "\n")
        rep2 = fleet.sync(base, 1)
        assert rep2.corrupt_lines == 0
        assert rep2.db.best("late[]", "t0").config == {"bm": 256}

    def test_cli_verify_fails_on_corrupt_lines(self, tmp_path, capsys):
        """`sync --verify` promises a lossless, divergence-free merge: a
        dropped corrupt line must fail it even when the best-record sets
        happen to match the reference."""
        ref = _store_with(tmp_path, "ref.jsonl", [_rec(op="good[]")])
        base = str(tmp_path / "f.jsonl")
        with open(fleet.shard_store_path(base, 0), "w") as f:
            f.write(_rec(op="good[]").to_json() + "\n")
            f.write('{"op": "torn')
        rc = cli.main(["sync", "--db", base, "--num-shards", "1",
                       "--verify", ref.path])
        err = capsys.readouterr().err
        assert rc == 1
        assert "corrupt" in err and "not lossless" in err


class TestAppendRetryCap:
    def test_vanishing_store_path_surfaces_instead_of_spinning(
            self, tmp_path, monkeypatch):
        db = ScheduleDatabase(str(tmp_path / "db.jsonl"))
        db.add(_rec())
        real_stat = os.stat

        def vanishing_stat(path, *args, **kwargs):
            if os.fspath(path) == db.path:
                raise FileNotFoundError(path)
            return real_stat(path, *args, **kwargs)

        monkeypatch.setattr(os, "stat", vanishing_stat)
        with pytest.raises(RuntimeError, match="keeps vanishing"):
            db.add(_rec(op="b[]"))


# -- stale snapshots (COST_MODEL_VERSION lifecycle) ------------------------

def _make_stale(snap_path: str, out_path: str, version: str = "cm0") -> str:
    """Rewrite a snapshot as if built under another cost-model version
    (the digest covers records only, so the file stays well-formed)."""
    with open(snap_path) as f:
        obj = json.load(f)
    obj["cost_model_version"] = version
    with open(out_path, "w") as f:
        json.dump(obj, f)
    return out_path


class TestStaleSnapshot:
    def _snapshot(self, tmp_path):
        db = _store_with(tmp_path, "db.jsonl", [_rec(op="m[]", bm=128)])
        snap = str(tmp_path / "cache.json")
        ScheduleCache.build(db.path, snap)
        return snap

    def test_load_rejects_version_mismatch(self, tmp_path):
        stale = _make_stale(self._snapshot(tmp_path),
                            str(tmp_path / "stale.json"))
        with pytest.raises(StaleSnapshotError) as ei:
            ScheduleCache.load(stale)
        msg = str(ei.value)
        assert "cm0" in msg and COST_MODEL_VERSION in msg
        assert "repro.tuna snapshot" in msg  # actionable: says how to fix

    def test_allow_stale_warns_and_flags(self, tmp_path):
        stale = _make_stale(self._snapshot(tmp_path),
                            str(tmp_path / "stale.json"))
        with pytest.warns(StaleSnapshotWarning):
            cache = ScheduleCache.load(stale, allow_stale=True)
        assert cache.stale and cache.cost_model_version == "cm0"
        assert len(cache) == 1  # records are there, keys just won't match

    def test_set_default_cache_refuses_stale_install(self, tmp_path):
        stale = _make_stale(self._snapshot(tmp_path),
                            str(tmp_path / "stale.json"))
        with pytest.raises(StaleSnapshotError):
            tuner.set_default_cache(stale)
        assert tuner.get_default_cache() is None  # nothing half-installed

    def test_env_cache_stale_flags_then_heals_on_republish(
            self, tmp_path, monkeypatch):
        """$REPRO_TUNA_CACHE at a stale snapshot resolves to OFF with a
        warning (not a crash, not silent misses) — and once the snapshot
        is rebuilt in place, refresh_default_cache picks it up without a
        process restart."""
        snap = self._snapshot(tmp_path)
        stale_at_same_path = str(tmp_path / "served.json")
        _make_stale(snap, stale_at_same_path)
        monkeypatch.setenv("REPRO_TUNA_CACHE", stale_at_same_path)
        monkeypatch.setattr(tuner, "_DEFAULT_CACHE", tuner._UNSET)
        monkeypatch.setattr(tuner, "_DEFAULT_CACHE_PATH", None)
        with pytest.warns(StaleSnapshotWarning, match="REPRO_TUNA_CACHE"):
            assert tuner.get_default_cache() is None

        db = ScheduleDatabase(str(tmp_path / "db.jsonl"))
        ScheduleCache.build(db.path, stale_at_same_path)  # rebuilt, current
        assert tuner.refresh_default_cache() is True
        assert tuner.get_default_cache().best("m[]", "t0") is not None

    def test_cli_query_stale_fails_with_actionable_message(
            self, tmp_path, capsys):
        stale = _make_stale(self._snapshot(tmp_path),
                            str(tmp_path / "stale.json"))
        rc = cli.main(["query", "--snapshot", stale, "--op", "m"])
        err = capsys.readouterr().err
        assert rc == 1
        assert "cm0" in err and "Rebuild" in err

    def test_cli_query_allow_stale_serves_and_warns(self, tmp_path, capsys):
        stale = _make_stale(self._snapshot(tmp_path),
                            str(tmp_path / "stale.json"))
        with pytest.warns(StaleSnapshotWarning):
            rc = cli.main(["query", "--snapshot", stale, "--op", "m",
                           "--allow-stale"])
        out = capsys.readouterr()
        assert rc == 0
        assert "m[]" in out.out and "WARNING" in out.err


# -- snapshot identity revalidation (hot reload correctness) ---------------

class TestContentDigestRevalidation:
    def test_preserved_mtime_and_size_still_reloads(self, tmp_path):
        """The old (mtime_ns, size) stamp is blind to a transport pull that
        preserves timestamps with an equal-size payload; the stored-sha1
        stamp is not."""
        db_a = _store_with(tmp_path, "db_a.jsonl", [_rec(op="m[]", bm=128,
                                                         score=1.0)])
        db_b = _store_with(tmp_path, "db_b.jsonl", [_rec(op="m[]", bm=256,
                                                         score=2.0)])
        snap = str(tmp_path / "cache.json")
        ScheduleCache.build(db_a.path, snap)
        st = os.stat(snap)
        tuner.set_default_cache(snap)
        assert tuner.get_default_cache().best("m[]", "t0").config == \
            {"bm": 128}

        ScheduleCache.build(db_b.path, snap)  # rsync --times equivalent:
        os.utime(snap, ns=(st.st_atime_ns, st.st_mtime_ns))
        now = os.stat(snap)
        assert (now.st_mtime_ns, now.st_size) == (st.st_mtime_ns, st.st_size)

        assert tuner.refresh_default_cache() is True
        cache = tuner.get_default_cache()
        assert cache.best("m[]", "t0").config == {"bm": 256}
        assert cache.hits == 1  # fresh instance: counters reset on swap

    def test_refresh_is_noop_without_change(self, tmp_path):
        snap = str(tmp_path / "cache.json")
        ScheduleCache.build(_store_with(tmp_path, "db.jsonl",
                                        [_rec()]).path, snap)
        tuner.set_default_cache(snap)
        first = tuner.get_default_cache()
        assert tuner.refresh_default_cache() is False
        assert tuner.get_default_cache() is first

    def test_refresh_survives_vanished_snapshot(self, tmp_path):
        snap = str(tmp_path / "cache.json")
        ScheduleCache.build(_store_with(tmp_path, "db.jsonl",
                                        [_rec()]).path, snap)
        tuner.set_default_cache(snap)
        first = tuner.get_default_cache()
        os.unlink(snap)  # mid-publish window
        assert tuner.refresh_default_cache() is False
        assert tuner.get_default_cache() is first  # keeps serving

    def test_header_probe_matches_full_parse(self, tmp_path):
        snap = str(tmp_path / "cache.json")
        built = ScheduleCache.build(
            _store_with(tmp_path, "db.jsonl",
                        [_rec(op=f"op{i}[]") for i in range(40)]).path, snap)
        hdr = read_snapshot_header(snap)
        assert hdr["sha1"] == built.payload_sha1()
        assert hdr["count"] == 40
        assert hdr["cost_model_version"] == COST_MODEL_VERSION


# -- SnapshotManager lifecycle ---------------------------------------------

class TestSnapshotManager:
    def test_ensure_is_content_addressed_and_idempotent(self, tmp_path):
        db = _store_with(tmp_path, "db.jsonl", [_rec(op="m[]")])
        mgr = SnapshotManager(db.path, str(tmp_path / "snaps"))
        info = mgr.ensure()
        assert info.rebuilt and info.repointed
        assert COST_MODEL_VERSION in info.name and info.sha1[:12] in info.name
        assert read_snapshot_header(mgr.latest_path)["snapshot"] == info.name

        again = mgr.ensure()  # cron-safe: nothing changed, nothing happens
        assert not again.rebuilt and not again.repointed
        assert again.name == info.name

        db.add(_rec(op="n[]", bm=256, score=0.5))
        moved = mgr.ensure()
        assert moved.rebuilt and moved.repointed and moved.name != info.name
        assert os.path.exists(info.path)  # old artifact left for late pulls

    def test_cost_model_bump_retires_the_snapshot_name(self, tmp_path,
                                                       monkeypatch):
        db = _store_with(tmp_path, "db.jsonl", [_rec(op="m[]")])
        mgr = SnapshotManager(db.path, str(tmp_path / "snaps"))
        old = mgr.ensure()
        monkeypatch.setattr("repro.tuna.cache.COST_MODEL_VERSION", "cm2")
        bumped = mgr.ensure()
        assert bumped.rebuilt and bumped.repointed
        assert ".cm2-" in bumped.name and bumped.name != old.name
        assert read_snapshot_header(mgr.latest_path)[
            "cost_model_version"] == "cm2"

    def test_load_follows_latest_pointer(self, tmp_path):
        db = _store_with(tmp_path, "db.jsonl", [_rec(op="m[]", bm=128)])
        mgr = SnapshotManager(db.path, str(tmp_path / "snaps"))
        mgr.ensure()
        cache = ScheduleCache.load(mgr.latest_path)
        assert cache.best("m[]", "t0").config == {"bm": 128}
        hdr = read_snapshot_header(mgr.latest_path)
        assert hdr["schema"] == POINTER_SCHEMA

    def test_hot_reload_through_latest_pointer(self, tmp_path):
        """The serving contract: point at `latest` once, republish forever.
        The pointer header carries the target sha1, so a repoint is a stamp
        change even though the pointer path never changes."""
        db = _store_with(tmp_path, "db.jsonl", [_rec(op="m[]", bm=128)])
        mgr = SnapshotManager(db.path, str(tmp_path / "snaps"))
        mgr.ensure()
        tuner.set_default_cache(mgr.latest_path)
        assert tuner.get_default_cache().best("m[]", "t0").config == \
            {"bm": 128}
        assert tuner.refresh_default_cache() is False

        db.add(_rec(op="m[]", bm=512, score=0.1))  # re-tuned: better record
        mgr.ensure()
        assert tuner.refresh_default_cache() is True
        assert tuner.get_default_cache().best("m[]", "t0").config == \
            {"bm": 512}

    def test_publish_reuses_ensure_info(self, tmp_path, monkeypatch):
        db = _store_with(tmp_path, "db.jsonl", [_rec(op="m[]")])
        mgr = SnapshotManager(db.path, str(tmp_path / "snaps"))
        info = mgr.ensure()
        monkeypatch.setattr(mgr, "ensure",
                            lambda *a, **k: pytest.fail("rebuilt twice"))
        manifests = mgr.publish(_mem(tmp_path), info=info)
        assert manifests[0].name == info.name

    def test_publish_roundtrip_serves_identically(self, tmp_path):
        db = _store_with(tmp_path, "db.jsonl",
                         [_rec(op="m[]"), _rec(op="n[]", bm=256)])
        mgr = SnapshotManager(db.path, str(tmp_path / "snaps"))
        t = _mem(tmp_path)
        manifests = mgr.publish(t)
        assert [m.name for m in manifests] == \
            [mgr.ensure().name, "schedule_cache.latest.json"]

        # "serving host": pull pointer + snapshot, nothing else shared
        host = tmp_path / "servehost"
        t.pull("schedule_cache.latest.json",
               str(host / "schedule_cache.latest.json"))
        target = read_snapshot_header(
            str(host / "schedule_cache.latest.json"))["snapshot"]
        t.pull(target, str(host / target))
        cache = ScheduleCache.load(str(host / "schedule_cache.latest.json"))
        assert cache.records() == ScheduleCache.from_db(db).records()
