"""repro.tuna schedule database + orchestrator + warm-cache integration.

Covers the subsystem contract: cm1 round-trip persistence, best-record
queries, compaction, parallel fan-out, and — the acceptance criterion — a
second ``tuner.tune`` against a warm DB returning the identical best config
with **zero** cost-model evaluations. Plus the cm1 golden: the feature
vector and score of one pinned schedule, so cost-model refactors must bump
``COST_MODEL_VERSION`` instead of silently invalidating stored records.
"""
import json

import numpy as np
import pytest

from repro.core import cost_model, tuner
from repro.core.cost_model import COST_MODEL_VERSION
from repro.core.spaces import BatchMatmulSpace, MatmulSpace
from repro.hw import get_target
from repro.tuna import orchestrator
from repro.tuna.db import ScheduleDatabase, ScheduleRecord

TPU = get_target("tpu_v5e")


def _rec(op="matmul[K=256,M=256,N=256,dtype_bytes=2]", target="tpu_v5e",
         score=1.0, **kw):
    return ScheduleRecord(op=op, target=target,
                          config={"bm": 256, "bn": 256, "bk": 256},
                          score=score, **kw)


class TestScheduleDatabase:
    def test_roundtrip_write_reload_query_best(self, tmp_path):
        path = tmp_path / "db.jsonl"
        db = ScheduleDatabase(path)
        db.add(_rec(score=2.0))
        db.add(_rec(score=1.0))            # improves
        db.add(_rec(score=5.0))            # worse: logged, not indexed
        db.add(_rec(op="other[]", score=3.0))

        re = ScheduleDatabase(path)
        assert re.lines_read == 4 and len(re) == 2
        best = re.best("matmul[K=256,M=256,N=256,dtype_bytes=2]", "tpu_v5e")
        assert best is not None and best.score == 1.0
        assert best.config == {"bm": 256, "bn": 256, "bk": 256}
        assert best.version == COST_MODEL_VERSION
        # version is part of the key: other cost-model versions don't match
        assert re.best("other[]", "tpu_v5e", version="cm0") is None

    def test_corrupt_lines_skipped(self, tmp_path):
        path = tmp_path / "db.jsonl"
        ScheduleDatabase(path).add(_rec(score=1.5))
        with open(path, "a") as f:
            f.write("{not json\n\n")
            f.write(json.dumps({"op": "x"}) + "\n")  # missing fields
        re = ScheduleDatabase(path)
        assert re.corrupt_lines == 2 and len(re) == 1

    def test_compact_drops_superseded_lines(self, tmp_path):
        path = tmp_path / "db.jsonl"
        db = ScheduleDatabase(path)
        for s in (4.0, 3.0, 2.0, 1.0):
            db.add(_rec(score=s))
        db.add(_rec(op="other[]", score=9.0))
        assert db.compact() == 3
        re = ScheduleDatabase(path)
        assert re.lines_read == 2 and len(re) == 2
        assert re.best("matmul[K=256,M=256,N=256,dtype_bytes=2]",
                       "tpu_v5e").score == 1.0

    def test_merge_and_export(self, tmp_path):
        a = ScheduleDatabase(tmp_path / "a.jsonl")
        a.add(_rec(score=2.0))
        b = ScheduleDatabase(tmp_path / "b.jsonl")
        b.add(_rec(score=1.0))                 # beats a's record
        b.add(_rec(op="other[]", score=7.0))   # new key
        b.add(_rec(score=3.0))                 # worse: not absorbed
        assert a.merge(str(tmp_path / "b.jsonl")) == 2
        assert a.best("matmul[K=256,M=256,N=256,dtype_bytes=2]",
                      "tpu_v5e").score == 1.0
        out = tmp_path / "out.json"
        assert a.export(str(out)) == 2
        assert len(json.loads(out.read_text())) == 2

    def test_query_prefix_and_filters(self, tmp_path):
        db = ScheduleDatabase()
        db.add(_rec(score=1.0))
        db.add(_rec(op="matmul[K=512,M=512,N=512,dtype_bytes=2]", score=2.0))
        db.add(_rec(op="conv2d[...]", target="cpu_avx2", score=3.0))
        assert len(db.query(op="matmul")) == 2
        assert len(db.query(target="cpu_avx2")) == 1
        assert len(db.query()) == 3


class TestSignature:
    def test_matches_legacy_record_format(self):
        s = MatmulSpace(4096, 4096, 4096, 2, target_kind="tpu")
        assert s.signature() == "matmul[K=4096,M=4096,N=4096,dtype_bytes=2]"
        b = BatchMatmulSpace(8, 128, 128, 64, 4, target_kind="tpu")
        assert b.signature() == "batch_matmul[Bsz=8,K=64,M=128,N=128,dtype_bytes=4]"

    def test_target_kind_not_in_signature(self):
        tpu = MatmulSpace(256, 256, 256, 4, target_kind="tpu")
        cpu = MatmulSpace(256, 256, 256, 4, target_kind="cpu")
        assert tpu.signature() == cpu.signature()


class TestWarmCache:
    def test_tune_zero_evaluations_on_warm_db(self, tmp_path, monkeypatch):
        """Acceptance: populate once, then an identical tune performs zero
        cost-model evaluations and returns the identical best config."""
        path = str(tmp_path / "db.jsonl")
        space = MatmulSpace(1024, 1024, 1024, 2, target_kind="tpu")
        cold = tuner.tune(space, TPU, db=path)
        assert not cold.from_db and cold.evaluations > 0

        calls = []
        real = cost_model.evaluate

        def counting(*a, **kw):
            calls.append(1)
            return real(*a, **kw)

        monkeypatch.setattr(cost_model, "evaluate", counting)
        warm = tuner.tune(MatmulSpace(1024, 1024, 1024, 2, "tpu"), TPU,
                          db=path)
        assert warm.from_db
        assert warm.evaluations == 0 and not calls
        assert warm.config == cold.config
        assert warm.score == cold.score

    def test_tune_zero_evaluations_from_snapshot_cache(self, tmp_path,
                                                       monkeypatch):
        """Acceptance: a warm hit served through ``ScheduleCache`` alone
        (no DB installed at all) performs zero cost-model evaluations."""
        from repro.tuna.cache import ScheduleCache

        path = str(tmp_path / "db.jsonl")
        space = MatmulSpace(1024, 1024, 1024, 2, target_kind="tpu")
        cold = tuner.tune(space, TPU, db=path)
        snap = str(tmp_path / "cache.json")
        ScheduleCache.build(path, snap)

        tuner.set_default_db(None)  # the snapshot serves on its own
        tuner.set_default_cache(snap)

        def boom(*a, **kw):
            raise AssertionError("cost model evaluated despite snapshot")

        monkeypatch.setattr(cost_model, "evaluate", boom)
        warm = tuner.tune(MatmulSpace(1024, 1024, 1024, 2, "tpu"), TPU)
        assert warm.from_db and warm.from_cache
        assert warm.evaluations == 0
        assert warm.config == cold.config and warm.score == cold.score
        assert tuner.get_default_cache().hits >= 1
        # and the snapshot never absorbs write-backs
        with pytest.raises(TypeError):
            tuner.get_default_cache().add(None)

    def test_env_cache_pointing_at_unbuilt_snapshot_is_off(self, tmp_path,
                                                           monkeypatch):
        """$REPRO_TUNA_CACHE naming a snapshot that hasn't been built yet
        must resolve to 'no cache', not crash every lookup."""
        monkeypatch.setenv("REPRO_TUNA_CACHE",
                           str(tmp_path / "not_built_yet.json"))
        monkeypatch.setattr(tuner, "_DEFAULT_CACHE", tuner._UNSET)
        assert tuner.get_default_cache() is None
        res = tuner.tune(MatmulSpace(256, 256, 256, 2, "tpu"), TPU, db=False)
        assert not res.from_db and res.evaluations > 0

    def test_flash_blocks_served_from_snapshot_cache(self, tmp_path,
                                                     monkeypatch):
        from repro.kernels import ops
        from repro.tuna.cache import ScheduleCache

        db = ScheduleDatabase(tmp_path / "db.jsonl")
        db.add(ScheduleRecord(
            op="flash[d=128,dtype_bytes=2,s=2048]", target="tpu_v5e",
            config={"block_q": 256, "block_k": 128}, score=1e-9))
        snap = str(tmp_path / "cache.json")
        ScheduleCache.build(db.path, snap)
        ops.use_schedule_cache(snap)  # clears the memo, installs the cache
        assert ops.tuned_flash_blocks(2048, 128) == (256, 128)
        assert tuner.get_default_cache().hits >= 1

    def test_tuned_matmul_blocks_served_from_default_db(self, tmp_path,
                                                        monkeypatch):
        path = str(tmp_path / "db.jsonl")
        space = MatmulSpace(2048, 2048, 2048, 2, target_kind="tpu")
        cfg, _ = tuner.best_schedule(space, TPU, db=path)

        tuner.set_default_db(path)  # also clears the lru memo

        def boom(*a, **kw):
            raise AssertionError("cost model evaluated despite warm DB")

        monkeypatch.setattr(cost_model, "evaluate", boom)
        bm, bn, bk = tuner.tuned_matmul_blocks(2048, 2048, 2048, 2)
        assert (bm, bn, bk) == (cfg["bm"], cfg["bn"], cfg["bk"])

    def test_rank_space_writes_back_best(self, tmp_path):
        db = ScheduleDatabase(tmp_path / "db.jsonl")
        space = MatmulSpace(512, 512, 512, 2, target_kind="tpu")
        ranked = tuner.rank_space(space, TPU, limit=1024, db=db)
        rec = db.best(space.signature(), "tpu_v5e")
        assert rec is not None
        assert rec.config == ranked[0][0] and rec.score == ranked[0][1]
        assert rec.meta["strategy"] == "exhaustive"
        # the centre config was enumerated, so its score is recorded and a
        # warm tune() can report a real default_score
        assert rec.meta["default_score"] == pytest.approx(
            dict((tuple(sorted(c.items())), s) for c, s in ranked)[
                tuple(sorted(space.default_config().items()))])

    def test_env_var_fallback_and_explicit_off(self, tmp_path, monkeypatch):
        path = str(tmp_path / "db.jsonl")
        tuner.tune(MatmulSpace(256, 256, 256, 2, "tpu"), TPU, db=path)
        monkeypatch.setenv("REPRO_TUNA_DB", path)
        monkeypatch.setattr(tuner, "_DEFAULT_DB", tuner._UNSET)
        warm = tuner.tune(MatmulSpace(256, 256, 256, 2, "tpu"), TPU)
        assert warm.from_db
        # explicit None switches the default off despite the env var
        tuner.set_default_db(None)
        assert tuner.get_default_db() is None

    def test_set_default_db_clears_flash_memo(self, tmp_path):
        from repro.kernels import ops

        heuristic = ops.tuned_flash_blocks(1024, 128)  # memoised, no DB
        db = ScheduleDatabase(tmp_path / "db.jsonl")
        db.add(ScheduleRecord(
            op="flash[d=128,dtype_bytes=2,s=1024]", target="tpu_v5e",
            config={"block_q": 128, "block_k": 128}, score=1e-9))
        tuner.set_default_db(db)
        assert ops.tuned_flash_blocks(1024, 128) == (128, 128)
        assert heuristic != (128, 128)  # proves the memo was refreshed


class TestOrchestrator:
    def test_fanout_two_spaces_pool_of_two(self, tmp_path):
        db = ScheduleDatabase(tmp_path / "db.jsonl")
        jobs = orchestrator.jobs_for(
            ["dense_256", "batch_matmul"], ["tpu_v5e"], limit=256)
        report = orchestrator.run(jobs, db=db, workers=2)
        assert report.ok and len(report.records) == 2
        assert len(db) == 2
        # results must equal what an in-process exhaustive search finds
        for job in jobs:
            space = orchestrator.build_space(job)
            expect_cfg, expect_score = tuner.rank_space(
                space, TPU, limit=256)[0]
            rec = db.best(space.signature(), "tpu_v5e")
            assert rec.config == expect_cfg
            assert rec.score == pytest.approx(expect_score)
        # persisted: a fresh reload serves the same records
        re = ScheduleDatabase(tmp_path / "db.jsonl")
        assert len(re) == 2

    def test_failures_reported_after_retries(self, tmp_path):
        db = ScheduleDatabase()
        jobs = [orchestrator.TuneJob(op="no_such_op", target="tpu_v5e"),
                orchestrator.TuneJob(op="dense_256", target="tpu_v5e",
                                     limit=64)]
        report = orchestrator.run(jobs, db=db, workers=1, retries=1)
        assert len(report.records) == 1 and len(report.failures) == 1
        fail = report.failures[0]
        assert fail.job.op == "no_such_op" and fail.attempts == 2
        assert "no_such_op" in fail.error


class TestCli:
    def test_smoke_tune_query_compact_export(self, tmp_path, capsys):
        from repro.tuna import cli

        db = str(tmp_path / "db.jsonl")
        assert cli.main(["tune", "--smoke", "--db", db, "--workers", "1"]) == 0
        assert cli.main(["query", "--db", db, "--target", "tpu_v5e"]) == 0
        out = capsys.readouterr().out
        assert "matmul[K=256,M=256,N=256,dtype_bytes=4]" in out
        assert cli.main(["compact", "--db", db]) == 0
        assert cli.main(["export", "--db", db,
                         "--out", str(tmp_path / "out.json")]) == 0
        assert cli.main(["query", "--db", db, "--op", "nope["]) == 1

    def test_unknown_op_rejected(self, tmp_path):
        from repro.tuna import cli

        rc = cli.main(["tune", "--db", str(tmp_path / "db.jsonl"),
                       "--ops", "bogus", "--targets", "tpu_v5e"])
        assert rc == 2


class TestGoldenCostModel:
    """Pin the cm1 feature vector + score of one fixed schedule. If this
    fails, the cost model changed meaning: bump COST_MODEL_VERSION (stored
    cm1 records are then ignored, not silently mis-scored) and re-pin."""

    GOLDEN_FEATURES = {
        "ilp_cycles": 51623.48146520146,
        "movement_bytes": 1572864.0,
        "unhidden_dma_cycles": 5537.469108669109,
        "arith_ops": 64.0,
        "ldst_ops": 0.0,
        "alignment_waste": 0.0,
        "occupancy_penalty": 0.0,
        "vmem_overflow": 0.0,
        "parallel_extent": 16,
        "dispatch_calls": 64.0,
    }
    GOLDEN_SCORE = 6.114623058737953e-05

    def test_version_is_cm1(self):
        assert COST_MODEL_VERSION == "cm1"

    def test_feature_vector_and_score_pinned(self):
        space = MatmulSpace(512, 512, 512, 2, target_kind="tpu")
        cfg = {"bm": 128, "bn": 128, "bk": 128, "double_buffer": True}
        prog, meta = space.instantiate(cfg)
        feats = cost_model.extract_features(prog, TPU, meta)
        got = feats.as_dict()
        assert set(got) == set(self.GOLDEN_FEATURES)
        for name, want in self.GOLDEN_FEATURES.items():
            assert got[name] == pytest.approx(want, rel=1e-9), name
        assert cost_model.score(feats, TPU) == pytest.approx(
            self.GOLDEN_SCORE, rel=1e-9)
