"""Extra coverage: conv/depthwise spaces, calibration, sharding tuner glue."""
import numpy as np
import pytest

from repro.configs.tuna_ops import OPERATORS
from repro.core import cost_model, extract_features
from repro.core.tuner import rank_space, tune
from repro.hw import get_target

CPU = get_target("cpu_avx2")
TPU = get_target("tpu_v5e")


class TestOperatorSpaces:
    @pytest.mark.parametrize("name", sorted(OPERATORS))
    def test_cpu_spaces_instantiate_and_score(self, name):
        space = OPERATORS[name]("cpu")
        cfg = space.default_config()
        prog, meta = space.instantiate(cfg)
        score = cost_model.evaluate(prog, CPU, meta)
        assert np.isfinite(score) and score > 0

    @pytest.mark.parametrize("name", ["dense_512", "conv2d", "batch_matmul"])
    def test_tpu_spaces_rank(self, name):
        space = OPERATORS[name]("tpu")
        ranked = rank_space(space, TPU, limit=64)
        assert len(ranked) >= 2
        assert ranked[0][1] <= ranked[-1][1]

    def test_depthwise_is_vpu_only(self):
        """Depthwise conv has no contraction — no MXU ops on TPU."""
        space = OPERATORS["depthwise_conv2d"]("tpu")
        prog, meta = space.instantiate(space.default_config())
        f = extract_features(prog, TPU, meta)
        from repro.core import count_instructions, lower_program

        rep = count_instructions(prog, lower_program(prog, TPU))
        assert rep.counts.get("mxu.matmul", 0) == 0
        assert f.arith_ops > 0  # vpu fma instead


class TestCalibration:
    def test_nnls_nonnegative_and_fits(self):
        from repro.core.calibrate import _nnls

        rng = np.random.default_rng(0)
        A = np.abs(rng.standard_normal((40, 4)))
        x_true = np.array([0.5, 0.0, 2.0, 0.1])
        y = A @ x_true
        x = _nnls(A, y, iters=5000)
        assert (x >= 0).all()
        np.testing.assert_allclose(A @ x, y, rtol=0.2, atol=0.1)

    def test_coeffs_for_scoring_shape(self):
        from repro.core.calibrate import coeffs_for_scoring

        c = coeffs_for_scoring({
            "ilp_cycles": 1e-9, "movement_bytes": 1e-10, "arith_ops": 0.0,
            "ldst_ops": 0.0, "dispatch_calls": 1e-6, "intercept": 0.0,
        })
        assert c["vmem_overflow"] == 1.0  # hard constraint survives


class TestDistributionSpace:
    def test_default_space_contents(self):
        from repro.core.sharding_tuner import default_space

        space = default_space("train", base_accum=16)
        assert {"accum_steps", "grad_compression", "sp_seq"} <= set(space[0])
        assert len(space) >= 8
        infer = default_space("prefill", base_accum=1)
        assert all(set(v) == {"sp_seq"} for v in infer)
